"""Fleet tier: multi-pod stream routing + elastic scaling.

One :class:`~repro.serving.server.PodServer` solves one edge pod; the
ROADMAP's north star is heavy traffic from millions of users, which
means MANY pods behind a router.  This module is that layer:

  * :class:`FleetServer` — owns N pods and drives the same open-loop
    phases a single pod runs (``open_loop_begin`` /
    ``serve_open_batch`` / ``open_loop_end``), with a
    :class:`RoutingPolicy` splitting the global arrival stream per pod.
    Every pod sees the shared ``loops``/``backends`` lists (global
    stream indices), so a stream's per-frame state — detection
    history, discovery, exploration cadence — migrates implicitly when
    its arrivals start landing on another pod.
  * :class:`LeastLoadedRouting` — sticky balance: a new stream lands on
    the active pod with the fewest assigned streams and stays there;
    scale events mark the overflow for lazy rebalance.
  * :class:`AffinityRouting` — consistent hashing on a content/variant
    affinity key (sha1 ring, ``vnodes`` virtual nodes per pod): streams
    sharing a key co-locate, so their same-variant requests merge into
    fuller batches — the fleet-level echo of variant batching.  Scale
    events rebuild the ring and only the streams whose arc moved
    migrate.
  * :class:`ElasticController` — grows/shrinks the active pod set on
    SUSTAINED SLO pressure (shed + missed + violated over offered, per
    control interval), heartbeating each pod's pressure into
    ``distributed/elastic.py``'s :class:`~repro.distributed.elastic.
    HealthTracker`; a retiring pod is DRAINED first (its queued and
    in-flight frames finish on it — nothing is dropped mid-flight) and
    its streams re-route on their next arrival.

A stream never migrates while its newest frame is still in flight on
its current pod: the depth-1 camera buffer (``missed`` accounting)
lives there, and moving mid-frame would double-serve or drop it.  All
routing/scaling state advances only on event-clock arrival times and
seeded identifiers, so fleet runs record and replay bit-identically
(``route``/``scale`` telemetry events; the replay-determinism lane
drives a 2-pod corpus).

Conservation, fleet-wide: every global arrival is routed to exactly
one pod, so ``len(arrivals) == sum over pods of (admitted + rejected
+ missed)`` — the per-pod law lifted through the router.
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
from typing import Callable, Sequence

import numpy as np

from repro.distributed.elastic import HealthTracker
from repro.serving.server import PodServer, ServeStats
from repro.serving.telemetry import TelemetrySink


def _ring_hash(label: str) -> int:
    """Position of ``label`` on the consistent-hash ring.  sha1, not
    Python ``hash()``: stable across processes (no PYTHONHASHSEED
    lottery), which the replay-determinism contract requires."""
    return int.from_bytes(hashlib.sha1(label.encode()).digest()[:8], "big")


def default_affinity_key(stream: int) -> str:
    """Content-class affinity key matching the synthetic corpora: the
    builders vary scene density as ``30 + 5 * (stream % 4)`` objects,
    so streams congruent mod 4 plan the same variant mix and batch
    together when co-located."""
    return f"c{stream % 4}"


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


class RoutingPolicy:
    """Stream -> pod binding decisions.

    ``assign`` answers where a stream SHOULD run given the fleet's
    current active set; :class:`FleetServer` owns when to ask (new
    stream, retired pod, scale event) and whether the move is safe
    (never mid-flight).  ``sticky`` policies keep an assigned stream
    where it is unless marked for reroute; non-sticky policies are
    re-consulted every arrival and the stream follows their answer.
    """

    name = "base"
    sticky = True

    def assign(self, stream: int, fleet: "FleetServer") -> int:
        raise NotImplementedError

    def on_scale(self, fleet: "FleetServer") -> None:
        """Active pod set changed (grow/shrink)."""

    def wants_reroute(self, stream: int) -> bool:
        """Whether a sticky policy marked ``stream`` for rebalance."""
        return False


class LeastLoadedRouting(RoutingPolicy):
    """Sticky least-loaded: new streams land on the active pod with the
    fewest ASSIGNED streams (ties break to the lower pod id — fully
    deterministic, no wall clock, no RNG).  On a scale event the
    overflow above the balanced share is marked for reroute and moves
    lazily — each marked stream re-assigns on its next SAFE arrival
    (not mid-flight), so a grow drains pressure without a stop-the-
    world reshuffle."""

    name = "least-loaded"
    sticky = True

    def __init__(self):
        self._reroute: set[int] = set()

    def assign(self, stream: int, fleet: "FleetServer") -> int:
        counts = fleet.assigned_counts()
        return min(fleet.active, key=lambda pid: (counts.get(pid, 0), pid))

    def on_scale(self, fleet: "FleetServer") -> None:
        counts = fleet.assigned_counts()
        streams = [s for s, pid in fleet.assignment.items()
                   if pid in counts]
        if not fleet.active:
            return
        target = -(-len(streams) // len(fleet.active))  # balanced share
        self._reroute.clear()
        for pid in fleet.active:
            mine = sorted(s for s, p in fleet.assignment.items()
                          if p == pid)
            # newest streams move first: their history is shortest, so
            # the migration perturbs the least accumulated state
            self._reroute.update(mine[target:])

    def wants_reroute(self, stream: int) -> bool:
        return stream in self._reroute

    def took_reroute(self, stream: int) -> None:
        self._reroute.discard(stream)


class AffinityRouting(RoutingPolicy):
    """Consistent hashing on a content/variant affinity key.

    Each active pod owns ``vnodes`` points on a sha1 ring; a stream
    maps to the first pod point at or after the hash of its affinity
    key.  Streams sharing a key therefore co-locate — their
    same-variant requests merge into fuller batches — and a scale
    event moves only the keys whose owning arc changed (the
    consistent-hashing guarantee), not the whole fleet.
    """

    name = "affinity"
    sticky = False

    def __init__(self, affinity_key: Callable[[int], str] | None = None,
                 vnodes: int = 16):
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        self.affinity_key = affinity_key or default_affinity_key
        self.vnodes = vnodes
        self._ring: list[tuple[int, int]] = []

    def _rebuild(self, fleet: "FleetServer") -> None:
        ring = []
        for pid in fleet.active:
            for v in range(self.vnodes):
                ring.append((_ring_hash(f"pod-{pid}-vnode-{v}"), pid))
        ring.sort()
        self._ring = ring

    def assign(self, stream: int, fleet: "FleetServer") -> int:
        if not self._ring:
            self._rebuild(fleet)
        h = _ring_hash(str(self.affinity_key(stream)))
        idx = bisect.bisect_left(self._ring, (h, -1)) % len(self._ring)
        return self._ring[idx][1]

    def on_scale(self, fleet: "FleetServer") -> None:
        self._rebuild(fleet)


ROUTINGS: dict[str, type[RoutingPolicy]] = {
    LeastLoadedRouting.name: LeastLoadedRouting,
    AffinityRouting.name: AffinityRouting,
}


def make_routing(spec, affinity_key=None) -> RoutingPolicy:
    """Resolve a routing spec: instance passes through, registered name
    constructs (``affinity_key`` applies to the affinity router)."""
    if isinstance(spec, RoutingPolicy):
        return spec
    try:
        cls = ROUTINGS[spec]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown routing policy {spec!r}; choose from "
            f"{sorted(ROUTINGS)} or pass a RoutingPolicy instance"
        ) from None
    if cls is AffinityRouting:
        return cls(affinity_key=affinity_key)
    return cls()


# ---------------------------------------------------------------------------
# elastic scaling
# ---------------------------------------------------------------------------


class ElasticController:
    """Grow/shrink the active pod set on sustained SLO pressure.

    Pressure over one control interval is the fleet's shed fraction:
    ``(rejected + missed + slo_violations) / max(arrivals, 1)`` deltas
    since the previous interval.  ``sustain`` consecutive hot
    intervals grow by one pod (up to ``max_pods``); ``sustain``
    consecutive cold intervals retire one (down to ``min_pods``) —
    single-step moves with hysteresis, the classic anti-flap shape.

    Every interval each pod heartbeats its OWN pressure into a
    :class:`~repro.distributed.elastic.HealthTracker` (the training
    stack's health machinery, with the serving-side dynamic-membership
    hooks): the shrink victim prefers the emptiest pod, and the
    tracker's straggler view (pressure far above the fleet median) is
    exported for operators via :meth:`stragglers`.
    """

    def __init__(self, min_pods: int = 1, max_pods: int = 8,
                 interval_s: float = 4.0, grow_threshold: float = 0.25,
                 shrink_threshold: float = 0.02, sustain: int = 2,
                 tracker: HealthTracker | None = None):
        if min_pods < 1 or max_pods < min_pods:
            raise ValueError(
                f"need 1 <= min_pods <= max_pods, got {min_pods}/{max_pods}")
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        if sustain < 1:
            raise ValueError(f"sustain must be >= 1, got {sustain}")
        self.min_pods = min_pods
        self.max_pods = max_pods
        self.interval_s = interval_s
        self.grow_threshold = grow_threshold
        self.shrink_threshold = shrink_threshold
        self.sustain = sustain
        self.health = tracker if tracker is not None else \
            HealthTracker(0, beat_interval=2 * interval_s)
        self._next_check = interval_s
        self._prev: dict[int, tuple[int, int, int, int]] = {}
        self._hot = 0
        self._cold = 0

    @staticmethod
    def _counts(stats: ServeStats) -> tuple[int, int, int, int]:
        return (stats.arrivals, stats.rejected, stats.missed,
                stats.slo_violations)

    def stragglers(self) -> list[int]:
        """Pods whose interval pressure ran far above the fleet median
        (the tracker's straggler rule on the heartbeat step times)."""
        return self.health.stragglers()

    def control(self, fleet: "FleetServer", t_s: float) -> None:
        """One control step at event time ``t_s`` (called by the fleet
        before routing each arrival round; cheap no-op between
        interval boundaries)."""
        if t_s < self._next_check:
            return
        # catch up in whole intervals so a traffic lull cannot queue a
        # burst of back-to-back control actions
        while self._next_check <= t_s:
            self._next_check += self.interval_s
        shed = offered = 0
        for pid in list(fleet.active):
            now = self._counts(fleet.pods[pid].stats)
            prev = self._prev.get(pid, (0, 0, 0, 0))
            self._prev[pid] = now
            d_arr = now[0] - prev[0]
            d_shed = sum(now[1:]) - sum(prev[1:])
            offered += d_arr
            shed += d_shed
            self.health.ensure_host(pid, t_s)
            self.health.heartbeat(pid, t_s,
                                  step_time=d_shed / max(d_arr, 1))
        self.health.tick(t_s)
        pressure = shed / max(offered, 1)
        if pressure >= self.grow_threshold:
            self._hot += 1
            self._cold = 0
        elif pressure <= self.shrink_threshold:
            self._cold += 1
            self._hot = 0
        else:
            self._hot = self._cold = 0
        if self._hot >= self.sustain and len(fleet.active) < self.max_pods:
            self._hot = 0
            fleet.grow(t_s, pressure)
        elif (self._cold >= self.sustain
              and len(fleet.active) > self.min_pods):
            self._cold = 0
            victim = self._pick_victim(fleet)
            self.health.remove_host(victim)
            self._prev.pop(victim, None)
            fleet.retire(victim, t_s, pressure)

    @staticmethod
    def _pick_victim(fleet: "FleetServer") -> int:
        """Retire the pod serving the fewest assigned streams (ties
        break to the HIGHEST pod id, so the founding pods persist and
        pod ids stay stable under repeated scale cycles)."""
        counts = fleet.assigned_counts()
        return min(fleet.active,
                   key=lambda pid: (counts.get(pid, 0), -pid))


# ---------------------------------------------------------------------------
# the fleet server
# ---------------------------------------------------------------------------


class _PodSink(TelemetrySink):
    """Tag every record of one pod with its pod id on the shared fleet
    sink.  ``EVENT_FIELDS`` validation tolerates extra keys, so the
    per-pod ``PodServer`` emit sites need no changes."""

    enabled = True

    def __init__(self, base: TelemetrySink, pod: int):
        self._base = base
        self._pod = pod

    def emit(self, event: str, **fields) -> None:
        self._base.emit(event, pod=self._pod, **fields)


@dataclasses.dataclass
class FleetStats:
    """Aggregate serving outcome of one fleet run.

    ``pod_stats`` holds every pod's final :class:`~repro.serving.
    server.ServeStats` in pod-id order — retired pods included, so the
    fleet-wide conservation law covers their frames too.  The summed
    counters mirror the single-pod fields; ``routes``/``migrations``/
    ``scale_ups``/``scale_downs`` are the fleet-only control-plane
    counters the replay fingerprint pins.
    """

    routing: str
    pod_ids: list[int]
    pod_stats: list[ServeStats]
    routes: int = 0
    migrations: int = 0
    scale_ups: int = 0
    scale_downs: int = 0

    def _sum(self, field: str) -> int:
        return sum(getattr(s, field) for s in self.pod_stats)

    @property
    def n_pods(self) -> int:
        return len(self.pod_stats)

    @property
    def arrivals(self) -> int:
        return self._sum("arrivals")

    @property
    def admitted(self) -> int:
        return self._sum("admitted")

    @property
    def degraded(self) -> int:
        return self._sum("degraded")

    @property
    def rejected(self) -> int:
        return self._sum("rejected")

    @property
    def missed(self) -> int:
        return self._sum("missed")

    @property
    def frames(self) -> int:
        return self._sum("frames")

    @property
    def dispatches(self) -> int:
        return self._sum("dispatches")

    @property
    def empty_frames(self) -> int:
        return self._sum("empty_frames")

    @property
    def slo_violations(self) -> int:
        return self._sum("slo_violations")

    @property
    def goodput_frames(self) -> int:
        return sum(s.goodput_frames for s in self.pod_stats)

    @property
    def useful_goodput_frames(self) -> int:
        return sum(s.useful_goodput_frames for s in self.pod_stats)

    @property
    def event_e2e(self) -> list[float]:
        out: list[float] = []
        for s in self.pod_stats:
            out.extend(s.event_e2e)
        return out

    @property
    def mean_queue_delay(self) -> float:
        delays: list[float] = []
        for s in self.pod_stats:
            delays.extend(s.queue_delays)
        return float(np.mean(delays)) if delays else 0.0

    def event_e2e_percentiles(self, qs=(50, 95, 99)) -> dict[int, float]:
        e2e = self.event_e2e
        if not e2e:
            return {q: 0.0 for q in qs}
        arr = np.asarray(e2e)
        return {q: float(np.percentile(arr, q)) for q in qs}


def format_fleet_report(stats: FleetStats, horizon_s: float) -> list[str]:
    """Human-readable fleet summary lines (the fleet sibling of
    ``format_open_loop_report``, shared by the serving drivers)."""
    pct = stats.event_e2e_percentiles()
    per_pod = ", ".join(
        f"p{pid}={s.admitted}adm/{s.rejected}rej"
        for pid, s in zip(stats.pod_ids, stats.pod_stats))
    return [
        f"fleet [{stats.routing} routing, {stats.n_pods} pods]: "
        f"{stats.arrivals} arrivals over {horizon_s:.1f}s -> "
        f"{stats.admitted} admitted ({stats.degraded} degraded), "
        f"{stats.rejected} rejected, {stats.missed} missed",
        f"control plane: {stats.routes} routes "
        f"({stats.migrations} migrations), "
        f"{stats.scale_ups} scale-ups, {stats.scale_downs} scale-downs",
        f"per pod: {per_pod}",
        f"useful goodput {stats.useful_goodput_frames} frames "
        f"({stats.useful_goodput_frames / max(horizon_s, 1e-9):.2f}/s), "
        f"event E2E p50/p95/p99 "
        f"{pct[50]:.3f}/{pct[95]:.3f}/{pct[99]:.3f}s",
    ]


class FleetServer:
    """N pods behind a router, driven on one global arrival clock.

    ``make_pod(pod_id)`` builds one :class:`PodServer`; every pod must
    be constructed over the SAME shared ``loops``/``backends`` lists so
    global stream indices (and each stream's accumulated per-frame
    state) are valid on any pod.  The fleet assigns the pod's
    telemetry sink itself (a :class:`_PodSink` tagging the shared
    sink), so ``make_pod`` should leave telemetry unset.

    ``elastic`` is an optional :class:`ElasticController`; without one
    the active set is fixed at ``n_pods``.  Routing, scaling and
    serving all advance on event-clock arrival times only — a fleet
    run over a seeded corpus is bit-reproducible and replayable.
    """

    def __init__(self, make_pod: Callable[[int], PodServer], n_pods: int,
                 *, routing="least-loaded",
                 elastic: ElasticController | None = None,
                 telemetry: TelemetrySink | None = None,
                 affinity_key: Callable[[int], str] | None = None):
        if n_pods < 1:
            raise ValueError(f"n_pods must be >= 1, got {n_pods}")
        self.make_pod = make_pod
        self.telemetry = telemetry if telemetry is not None \
            else TelemetrySink()
        self.routing = make_routing(routing, affinity_key=affinity_key)
        self.elastic = elastic
        self.pods: dict[int, PodServer] = {}
        self.active: list[int] = []
        self.assignment: dict[int, int] = {}
        self.slo_s: float | None = None
        self.routes = 0
        self.migrations = 0
        self.scale_ups = 0
        self.scale_downs = 0
        self._began = False
        for _ in range(n_pods):
            self._add_pod()

    # -- pod lifecycle -----------------------------------------------------

    def _add_pod(self) -> int:
        pid = len(self.pods)
        pod = self.make_pod(pid)
        if self.telemetry.enabled:
            pod.telemetry = _PodSink(self.telemetry, pid)
        self.pods[pid] = pod
        self.active.append(pid)
        if self._began:
            pod.open_loop_begin(self.slo_s)
        return pid

    def grow(self, t_s: float, pressure: float) -> int:
        """Add one pod to the active set (elastic scale-up)."""
        pid = self._add_pod()
        self.scale_ups += 1
        self.routing.on_scale(self)
        if self.telemetry.enabled:
            self.telemetry.emit("scale", t_s=t_s, action="grow", pod=pid,
                                n_pods=len(self.active), pressure=pressure)
        return pid

    def retire(self, pid: int, t_s: float, pressure: float) -> None:
        """Drain and retire one pod (elastic scale-down): its queued
        and in-flight frames FINISH on it — no stream is dropped
        mid-flight — and its streams re-route on their next arrival
        (their assignment now points at a retired pod)."""
        if pid not in self.active:
            raise ValueError(f"pod {pid} is not active")
        if len(self.active) == 1:
            raise ValueError("cannot retire the last active pod")
        self.active.remove(pid)
        self.pods[pid].open_loop_end()  # the retiring drain
        self.scale_downs += 1
        self.routing.on_scale(self)
        if self.telemetry.enabled:
            self.telemetry.emit("scale", t_s=t_s, action="shrink", pod=pid,
                                n_pods=len(self.active), pressure=pressure)

    def assigned_counts(self) -> dict[int, int]:
        """Streams currently assigned per ACTIVE pod (the least-loaded
        signal; retired-pod assignments are pending migrations and
        count for nobody)."""
        counts = {pid: 0 for pid in self.active}
        for pid in self.assignment.values():
            if pid in counts:
                counts[pid] += 1
        return counts

    # -- routing -----------------------------------------------------------

    def _safe_to_move(self, stream: int, pid: int) -> bool:
        """A stream may only migrate between frames: its newest frame
        on the current pod must have finished (the depth-1 camera
        buffer and ``missed`` accounting live there)."""
        entry = self.pods[pid]._stream_frame.get(stream)
        return entry is None or entry.complete

    def _route(self, arrival) -> int:
        s = arrival.stream
        pid = self.assignment.get(s)
        reason = None
        if pid is None:
            pid = self.routing.assign(s, self)
            reason = "new"
        elif pid not in self.pods or pid not in self.active:
            # the previous pod retired (and drained: nothing of this
            # stream is in flight there) — migrate through the router
            pid = self.routing.assign(s, self)
            reason = "migrate"
        elif self.routing.sticky:
            if (self.routing.wants_reroute(s)
                    and self._safe_to_move(s, pid)):
                new = self.routing.assign(s, self)
                if hasattr(self.routing, "took_reroute"):
                    self.routing.took_reroute(s)
                if new != pid:
                    pid, reason = new, "rebalance"
        else:
            new = self.routing.assign(s, self)
            if new != pid and self._safe_to_move(s, pid):
                pid, reason = new, "rebalance"
        if reason is not None:
            self.assignment[s] = pid
            self.routes += 1
            if reason != "new":
                self.migrations += 1
            if self.telemetry.enabled:
                self.telemetry.emit("route", t_s=arrival.t_s, stream=s,
                                    pod=pid, reason=reason)
        return pid

    # -- serving -----------------------------------------------------------

    def run_open_loop(self, traffic, *, slo_s: float | None = None
                      ) -> FleetStats:
        """Serve one open-loop traffic trace across the fleet.

        The same batched arrival rounds as ``PodServer.run_open_loop``
        — same-instant arrivals share one admission + drain round —
        except each round is split per pod by the router, with the
        elastic controller stepping BEFORE routing (so a pod retiring
        now stops receiving arrivals now, and a pod added now serves
        this very round)."""
        arrivals = traffic.arrivals() if hasattr(traffic, "arrivals") \
            else list(traffic)
        self.slo_s = slo_s
        self._began = True
        for pid in self.active:
            self.pods[pid].open_loop_begin(slo_s)
        i, n = 0, len(arrivals)
        while i < n:
            t = arrivals[i].t_s
            batch = []
            while i < n and arrivals[i].t_s <= t + 1e-12:
                batch.append(arrivals[i])
                i += 1
            if self.elastic is not None:
                self.elastic.control(self, t)
            if slo_s is not None:
                # fleet-global SLO envelope: each pod's fixed point
                # prices this round against the FLEET's residual budget
                # — the SLO minus the worst busy horizon any active pod
                # has already committed past now — instead of a private
                # per-pod envelope.  Pods co-scheduled behind one
                # router share the tail; admitting against the full
                # SLO while a sibling's backlog has spent part of it is
                # exactly the ≥4-pod p99 overshoot this closes.
                worst = max((max(0.0, self.pods[pid].clock.horizon() - t)
                             for pid in self.active), default=0.0)
                env = max(0.0, slo_s - worst)
                for pid in self.active:
                    self.pods[pid].solve_slo_s = env
            per_pod: dict[int, list] = {}
            for a in batch:
                per_pod.setdefault(self._route(a), []).append(a)
            for pid in sorted(per_pod):
                self.pods[pid].serve_open_batch(per_pod[pid])
        for pid in self.active:
            self.pods[pid].open_loop_end()
        return self.fleet_stats()

    def fleet_stats(self) -> FleetStats:
        pod_ids = sorted(self.pods)
        return FleetStats(
            routing=self.routing.name,
            pod_ids=pod_ids,
            pod_stats=[self.pods[pid].stats for pid in pod_ids],
            routes=self.routes,
            migrations=self.migrations,
            scale_ups=self.scale_ups,
            scale_downs=self.scale_downs,
        )


def make_fleet_pods(n_streams: int, *, make_loop, make_backend,
                    pod_server_kwargs: dict | None = None
                    ) -> tuple[Sequence, Sequence, Callable[[int], PodServer]]:
    """Convenience builder: one shared ``loops``/``backends`` pair and
    a ``make_pod`` factory over them (what :class:`FleetServer`
    requires — every pod must see the same stream lists).

    ``make_loop(stream, backend)`` / ``make_backend(stream)`` build
    the per-stream state once; ``pod_server_kwargs(pod_id)`` (a dict
    or a callable returning one) parameterises each pod — placement
    and policy instances must NOT be shared across pods, so pass a
    callable when using either."""
    backends = [make_backend(s) for s in range(n_streams)]
    loops = [make_loop(s, b) for s, b in enumerate(backends)]

    def make_pod(pod_id: int) -> PodServer:
        kw = pod_server_kwargs or {}
        if callable(kw):
            kw = kw(pod_id)
        return PodServer(loops, backends, **kw)

    return loops, backends, make_pod

"""Open-loop arrival-clocked traffic for the pod serving runtime.

Everything served before this module was CLOSED-loop: ``PodServer.step``
advanced one global ``frame_idx`` per tick, so every stream always had
a frame ready and the pod only ever saw exactly the load it could
clear.  Real cameras emit at ``1/fps`` over a shaped, jittery uplink,
users connect and drop, and load is diurnal/bursty — the open-loop
regime in which edge-analytics serving is actually judged (offered
load, not capacity, on the x-axis).

This module is the traffic side of that regime:

  * :class:`StreamClock` — one camera's emission clock.  Inter-arrival
    times are ``1/fps`` with seeded multiplicative lognormal jitter —
    the exact RNG discipline of
    :class:`repro.serving.network.NetworkModel` (``np.random.
    default_rng(seed)`` + ``exp(normal(0, jitter))``), so a jittery
    uplink and a jittery camera share one reproducibility story.
    Per-stream clocks are strictly monotone (jitter is multiplicative
    on a positive interval) and fully determined by ``(seed, stream)``.
  * :class:`ChurnEvent` — a connect/disconnect edge for one stream.  A
    disconnected camera emits nothing (its timeline keeps running; no
    frames are fabricated for the gap) and its per-stream frame index
    only advances on real emissions.
  * rate traces — piecewise-constant fps multipliers
    (``(t_start_s, scale)`` steps) model bursts and diurnal load
    without touching the per-stream clock discipline.
  * :class:`ArrivalProcess` — merges the per-stream clocks, churn and
    rate trace into one time-ordered :class:`Arrival` sequence over a
    horizon.  ``PodServer.run_open_loop`` consumes it: the event clock
    advances to the next arrival or completion, streams join/leave
    mid-run, and frames that miss their interval are counted, not
    fabricated.

The conservation law the property tests pin: every arrival is exactly
one of admitted / rejected (admission control) / missed (superseded in
the depth-1 camera buffer), and every admitted frame finishes.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One camera frame hitting the pod's front door.

    ``t_s`` is the absolute emission time on the event clock;
    ``frame_idx`` is the per-stream frame counter (only real emissions
    advance it, so simulation backends replay the right ground truth).
    """

    t_s: float
    stream: int
    frame_idx: int


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """A connect (``connected=True``) or disconnect edge for a stream."""

    t_s: float
    stream: int
    connected: bool


class StreamClock:
    """One camera's emission clock: ``1/fps`` spacing, seeded jitter.

    ``next_arrival()`` returns strictly increasing times: the jitter is
    multiplicative lognormal on a positive base interval (the
    ``NetworkModel`` discipline), so no draw can stall or reverse the
    clock.  ``rate_trace`` is an optional sorted sequence of
    ``(t_start_s, scale)`` steps: the interval consumed at time ``t``
    is divided by the scale of the segment containing ``t`` (scale 2.0
    = a 2x burst; scale 0.5 = a lull).
    """

    def __init__(self, stream: int, fps: float, jitter: float = 0.0,
                 seed: int = 0, start_s: float = 0.0,
                 rate_trace: Sequence[tuple[float, float]] = ()):
        if fps <= 0:
            raise ValueError(f"fps must be > 0, got {fps}")
        if jitter < 0:
            raise ValueError(f"jitter must be >= 0, got {jitter}")
        for t, scale in rate_trace:
            if scale <= 0:
                raise ValueError(f"rate_trace scale must be > 0, got "
                                 f"{scale} at t={t}")
        self.stream = stream
        self.fps = fps
        self.jitter = jitter
        self.rate_trace = tuple(sorted(rate_trace))
        # per-stream derived seed: one process seed reproduces every
        # stream, and streams never share a jitter sequence
        self._rng = np.random.default_rng((seed, stream))
        self._t = start_s

    def _scale_at(self, t: float) -> float:
        scale = 1.0
        for t0, s in self.rate_trace:
            if t >= t0:
                scale = s
        return scale

    def next_arrival(self) -> float:
        """Advance to (and return) the next emission time."""
        dt = 1.0 / (self.fps * self._scale_at(self._t))
        if self.jitter > 0:
            dt *= float(np.exp(self._rng.normal(0.0, self.jitter)))
        self._t += dt
        return self._t


class ArrivalProcess:
    """Merged open-loop traffic over ``n_streams`` cameras.

    ``fps`` is a scalar (shared) or one value per stream; ``churn`` is
    a sequence of :class:`ChurnEvent` (a stream whose FIRST event is a
    connect starts disconnected — late joiners; otherwise streams start
    connected).  ``rate_trace`` applies to every stream.  Arrivals are
    materialised up to ``horizon_s`` and returned sorted by
    ``(t_s, stream)`` — deterministic under a fixed seed.
    """

    def __init__(self, n_streams: int, fps: float | Sequence[float] = 0.5,
                 jitter: float = 0.0, seed: int = 0, horizon_s: float = 30.0,
                 churn: Iterable[ChurnEvent] = (),
                 rate_trace: Sequence[tuple[float, float]] = (),
                 start_s: float = 0.0):
        if n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {n_streams}")
        if horizon_s <= start_s:
            raise ValueError(
                f"horizon_s {horizon_s} must exceed start_s {start_s}")
        self.n_streams = n_streams
        self.fps = tuple(fps) if isinstance(fps, (tuple, list)) \
            else (float(fps),) * n_streams
        if len(self.fps) != n_streams:
            raise ValueError(
                f"got {len(self.fps)} fps values for {n_streams} streams")
        self.jitter = jitter
        self.seed = seed
        self.horizon_s = horizon_s
        self.start_s = start_s
        self.churn = tuple(sorted(churn, key=lambda e: (e.t_s, e.stream)))
        for e in self.churn:
            if not 0 <= e.stream < n_streams:
                raise ValueError(f"churn event for unknown stream {e.stream}")
        self.rate_trace = tuple(rate_trace)

    def _connected_intervals(self, stream: int) -> list[tuple[float, float]]:
        """The [on, off) windows of one stream over the horizon."""
        events = [e for e in self.churn if e.stream == stream]
        # a stream whose first churn edge is a CONNECT is a late joiner
        connected = not (events and events[0].connected)
        t_on = self.start_s
        out = []
        for e in events:
            if e.connected and not connected:
                connected, t_on = True, e.t_s
            elif not e.connected and connected:
                connected = False
                if e.t_s > t_on:
                    out.append((t_on, e.t_s))
        if connected:
            out.append((t_on, self.horizon_s))
        return out

    def arrivals(self) -> list[Arrival]:
        """The full traffic trace, sorted by ``(t_s, stream)``."""
        out: list[Arrival] = []
        for s in range(self.n_streams):
            clock = StreamClock(s, self.fps[s], self.jitter, self.seed,
                                self.start_s, self.rate_trace)
            windows = self._connected_intervals(s)
            frame_idx = 0
            t = clock.next_arrival()
            while t < self.horizon_s:
                # the camera timeline keeps running while disconnected;
                # only frames emitted inside an ON window exist
                if any(lo <= t < hi for lo, hi in windows):
                    out.append(Arrival(t_s=t, stream=s, frame_idx=frame_idx))
                    frame_idx += 1
                t = clock.next_arrival()
        out.sort(key=lambda a: (a.t_s, a.stream))
        return out

    def offered_rate(self) -> float:
        """Offered load in frames per second over the horizon."""
        return len(self.arrivals()) / (self.horizon_s - self.start_s)


def split_arrivals(arrivals: Iterable[Arrival],
                   assignment: dict[int, int]) -> dict[int, list[Arrival]]:
    """Partition a time-ordered arrival trace per pod.

    ``assignment`` maps stream -> pod id (the fleet router's binding
    table).  Each pod's sub-trace keeps the global order, so driving
    every sub-trace through its own ``PodServer.run_open_loop`` is
    equivalent to the fleet's batched round-robin when the assignment
    is static.  Raises on a stream the assignment does not cover —
    silently dropping traffic would break the fleet conservation law
    (``arrivals == sum(per-pod admitted + rejected + missed)``).
    """
    out: dict[int, list[Arrival]] = {}
    for a in arrivals:
        try:
            pod = assignment[a.stream]
        except KeyError:
            raise ValueError(
                f"arrival for stream {a.stream} has no pod assignment"
            ) from None
        out.setdefault(pod, []).append(a)
    return out


def arrivals_from_records(records) -> list[Arrival]:
    """Rebuild a time-ordered :class:`Arrival` list from telemetry
    ``arrival`` records (``repro.serving.telemetry``).

    This is the replay harness's traffic source: instead of
    regenerating an :class:`ArrivalProcess` from its seed, the replay
    re-drives the EXACT arrivals a recorded run saw (float64 times
    round-trip JSON exactly), so churn and rate traces are baked into
    the trace and never need reconstructing.  Records of other event
    types are ignored, so a whole event log can be passed verbatim.
    """
    out = [Arrival(t_s=r["t_s"], stream=r["stream"],
                   frame_idx=r["frame_idx"])
           for r in records if r.get("event", "arrival") == "arrival"]
    out.sort(key=lambda a: (a.t_s, a.stream))
    return out

"""Latency model + inference backends + the OmniSense scheduler glue.

``OmniSenseLatencyModel`` computes the allocator's (d_pre, d_inf)
matrices exactly as section IV-C specifies:

    d_pre[i][j] = projection(PI at model i's input size)
                  + encode(same) if model i runs remotely
    d_inf[i][j] = delivery(PI bytes) if remote else 0
                  + model i's profiled inference time

Row 0 is the zero-cost "skip" pseudo-model.  Delivery delays come from
the passive profiler (omega-window) scaled by payload size, and the
projection/encode terms from the offline stage-cost profile — the PI
resolution always equals the allocated model's input size ("to avoid
resizing the image").

Backends:
  * ``OracleBackend`` — samples detections from the scene ground truth
    using each variant's gav as hit probability (+ box jitter, rare
    false positives).  Drives the reproduction benchmark (DESIGN.md
    section 7: no pretrained weights exist, the systems claim is about
    allocation given a ladder).
  * ``JaxDetectorBackend`` — really projects the SRoI (Pallas gnomonic
    kernel) and runs the JAX detector ladder; used by examples/tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import sroi as sroi_mod
from repro.core.sphere import pi_box_to_sphbb
from repro.data.synthetic import SyntheticVideo
from repro.serving.network import NetworkModel, PassiveProfiler
from repro.serving.profiles import StageCosts


class OmniSenseLatencyModel:
    def __init__(self, costs: StageCosts, network: NetworkModel,
                 profiler: PassiveProfiler | None = None,
                 batch_marginal: float = 0.15,
                 pre_batch_marginal: float = 0.35):
        self.costs = costs
        self.network = network
        # a defaulted profiler inherits the link's RTT floor so its
        # payload rescaling never shrinks the fixed round-trip term
        self.profiler = profiler or PassiveProfiler(rtt_s=network.rtt_s)
        # marginal cost of each item beyond the first in a batched
        # forward (the standard sub-linear batching curve)
        self.batch_marginal = batch_marginal
        # same curve for the mobile-side projection/encode stage —
        # shallower batching than the edge forward (the mobile SoC
        # pipelines crops but streams encode mostly serially)
        self.pre_batch_marginal = pre_batch_marginal

    def _pre(self, variant: acc_mod.ModelProfile) -> float:
        mpix = variant.input_size ** 2 / 1e6
        t = self.costs.project_s_per_mpix * mpix
        if variant.location != "device":
            t += self.costs.encode_s_per_mpix * mpix
        return t

    def _inf(self, variant: acc_mod.ModelProfile) -> float:
        t = variant.infer_s
        if variant.location != "device":
            n_bytes = variant.input_size ** 2 * self.costs.bytes_per_pixel
            est = self.profiler.estimate(variant.name)
            if est == self.profiler.initial_s:
                t += self.network.delivery_delay(n_bytes)
            else:
                t += est
        return t

    def delays(self, srois: Sequence[sroi_mod.SRoI],
               variants: Sequence[acc_mod.ModelProfile]):
        r = len(srois)
        m = len(variants)
        d_pre = np.zeros((1 + m, r))
        d_inf = np.zeros((1 + m, r))
        for i, var in enumerate(variants):
            d_pre[1 + i, :] = self._pre(var)
            d_inf[1 + i, :] = self._inf(var)
        return d_pre, d_inf

    def batched_inference_delay(self, variant: acc_mod.ModelProfile,
                                batch_size: int) -> float:
        """Cost of ONE batched forward serving ``batch_size`` PIs.

        Per-batch fixed cost (the b=1 forward: dispatch, weight
        streaming and — for remote variants — the bundled payload
        delivery) plus a ``batch_marginal`` fraction of it for every
        additional item.  ``batch_size == 1`` reduces exactly to the
        per-request :meth:`_inf` term, so the allocator's utility
        ordering (which prices requests individually) is unchanged by
        the batched serving path; the pod server charges this instead
        of summing ``_inf`` per request.
        """
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        return self._inf(variant) * (
            1.0 + (batch_size - 1) * self.batch_marginal)

    def amortized_inference_delay(self, variant: acc_mod.ModelProfile,
                                  batch_size: int) -> float:
        """Per-item share of a batched forward (decreasing in batch)."""
        return self.batched_inference_delay(variant, batch_size) / batch_size

    def sharded_inference_delay(self, variant: acc_mod.ModelProfile,
                                batch_size: int, n_devices: int = 1) -> float:
        """Cost of one batched forward sharded over a replica group.

        The batch splits evenly over the group's ``data`` axis, so the
        critical path is the largest per-device shard; ``n_devices == 1``
        reduces exactly to :meth:`batched_inference_delay`.
        """
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        per_device = -(-batch_size // n_devices)  # ceil division
        return self.batched_inference_delay(variant, per_device)

    def tick_inference_delay(self, group_costs) -> float:
        """Device-aware cost of one pod tick.

        ``group_costs``: per replica group, the summed delays of the
        dispatches it executed this tick.  Dispatches within a group
        serialise; groups run concurrently on disjoint devices, so the
        tick pays the MAX over groups — the single-device pod (one
        group) degenerates to the old sum-over-dispatches.
        """
        return max(group_costs, default=0.0)

    def tick_overlap_delay(self, group_costs: dict,
                           carry_in: dict | None = None) -> float:
        """:meth:`tick_inference_delay` generalised to overlapping
        dispatches (the event-clock runtime, ``repro.serving.runtime``).

        ``group_costs`` maps replica-group index to the summed delays
        of the dispatches the tick ADDED to that group; ``carry_in``
        maps group index to the busy seconds the group still owed past
        the tick start (work launched in an earlier tick under an
        async drain policy).  Each group completes at carry-in plus
        its serialised new work and the tick pays the max — with no
        carry-in this is exactly :meth:`tick_inference_delay`, which
        is what pins the sync policy's bit-identity.  ``PodServer``'s
        flush prices the carried tail through this closed form (with
        the event horizon as the floor for untouched busy groups).
        """
        carry = carry_in or {}
        return max((carry.get(g, 0.0) + c for g, c in group_costs.items()),
                   default=0.0)

    def variant_queue_cost(self, variant: acc_mod.ModelProfile,
                           n_requests: int, buckets=None,
                           n_devices: int = 1) -> float:
        """Device-busy seconds of draining ``n_requests`` of ``variant``.

        Exactly the variant's contribution to its replica group in one
        tick schedule: the requests split into bucket-capped chunks
        (``ShapeBuckets.split``) and each chunk is one sharded batched
        forward (:meth:`sharded_inference_delay`) — the same curve
        :meth:`tick_schedule_delay` prices, so the pod-level allocator
        and the tick model can never disagree on what a queue costs.
        Without ``buckets`` the whole count is one dispatch.
        """
        if n_requests <= 0:
            return 0.0
        chunks = buckets.split(n_requests) if buckets is not None \
            else [n_requests]
        return sum(self.sharded_inference_delay(variant, b, n_devices)
                   for b in chunks)

    def pod_amortization(self, variant: acc_mod.ModelProfile,
                         batch_size: int, buckets=None,
                         n_devices: int = 1) -> float:
        """Per-request share of the variant's tick drain, relative to
        the b=1 forward.

        ``== 1.0`` exactly at ``batch_size == 1`` on one device (the
        b=1 pin that keeps uncoupled plans byte-identical), decreasing
        as co-streams share the batch and as the replica group widens.
        The pod allocator scales each stream's base ``d_inf`` row by
        this factor, so coupling inherits whatever per-stream delivery
        estimates the base matrices carry.
        """
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        total = self.variant_queue_cost(variant, batch_size, buckets,
                                        n_devices)
        return total / (batch_size * self.batched_inference_delay(variant, 1))

    def batched_pre_delay(self, variant: acc_mod.ModelProfile,
                          batch_size: int) -> float:
        """Cost of projecting/encoding ``batch_size`` PIs as one batch.

        The :meth:`_pre` stage follows the same sub-linear curve as the
        edge forward, with its own (shallower) ``pre_batch_marginal``;
        ``batch_size == 1`` reduces exactly to the per-request term.
        """
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        return self._pre(variant) * (
            1.0 + (batch_size - 1) * self.pre_batch_marginal)

    def pre_amortization(self, variant: acc_mod.ModelProfile,
                         batch_size: int) -> float:
        """Per-request share of the batched mobile-side stage, relative
        to the b=1 projection/encode.

        ``== 1.0`` EXACTLY at ``batch_size == 1`` (the identity pin
        that keeps uncoupled d_pre pricing byte-identical), decreasing
        as co-streams share the mobile stage.  ``solve_pod``'s coupled
        price scales each stream's ``d_pre`` row by this factor.
        """
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        pre = self._pre(variant)
        if pre <= 0.0:
            return 1.0
        return self.batched_pre_delay(variant, batch_size) / \
            (batch_size * pre)

    def tick_schedule_delay(self, schedule):
        """Price a whole tick's dispatch schedule on the pure curve.

        ``schedule``: one ``(variant, batch_size, n_devices,
        group_index)`` tuple per dispatch.  Returns ``(tick_delay,
        per-group sums)`` — the projection ``benchmarks/serving_bench``
        records, kept here so a future curve change cannot silently
        diverge from the serving path's pricing (``PodServer`` adds
        execution detail — marginal overrides, per-backend forwards —
        on top of these same methods).
        """
        group_sums: dict = {}
        for variant, batch_size, n_devices, gidx in schedule:
            group_sums[gidx] = group_sums.get(gidx, 0.0) + \
                self.sharded_inference_delay(variant, batch_size, n_devices)
        return self.tick_inference_delay(group_sums.values()), group_sums

    def observe_delivery(self, variant: acc_mod.ModelProfile) -> float:
        """Simulate one remote delivery, feed the passive profiler."""
        n_bytes = variant.input_size ** 2 * self.costs.bytes_per_pixel
        d = self.network.delivery_delay(n_bytes)
        self.profiler.observe(variant.name, d)
        return d


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------


def _in_sroi(det: sroi_mod.Detection, region: sroi_mod.SRoI) -> bool:
    ct, cp = region.center
    fh, fv = region.fov
    dlon = abs((det.box[0] - ct + math.pi) % (2 * math.pi) - math.pi)
    return dlon <= fh / 2 and abs(det.box[1] - cp) <= fv / 2


def _fully_enclosed(det: sroi_mod.Detection, region: sroi_mod.SRoI) -> bool:
    ct, cp = region.center
    fh, fv = region.fov
    dlon = abs((det.box[0] - ct + math.pi) % (2 * math.pi) - math.pi)
    return (dlon + det.box[2] / 2 <= fh / 2
            and abs(det.box[1] - cp) + det.box[3] / 2 <= fv / 2)


def _angular_distance(det: sroi_mod.Detection, region: sroi_mod.SRoI) -> float:
    ct, cp = region.center
    dlon = abs((det.box[0] - ct + math.pi) % (2 * math.pi) - math.pi)
    # great-circle distance (spherical law of cosines)
    cosd = (math.sin(cp) * math.sin(det.box[1])
            + math.cos(cp) * math.cos(det.box[1]) * math.cos(dlon))
    return math.acos(max(-1.0, min(1.0, cosd)))


@dataclasses.dataclass
class OracleBackend:
    """Ground-truth-driven detection sampling (see module docstring).

    ``semantic_batch``: the batched entry point is a pure simulation
    (no accelerator behind it), so the pod server prices a drained
    chunk spanning per-stream oracle instances as ONE shared-
    accelerator dispatch — the regime being simulated.
    """

    video: SyntheticVideo
    frame: int = 0
    seed: int = 0
    fp_rate: float = 0.02
    semantic_batch = True  # class-level: not a dataclass field

    def set_frame(self, frame: int) -> None:
        self.frame = frame

    def _detect(self, candidates, variant, region_tag: int,
                ref_sr: float = 4 * math.pi,
                region: sroi_mod.SRoI | None = None):
        out = []
        n_cat = self.video.n_categories
        fp_rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.frame) * 131 + variant.index * 7
            + region_tag)
        for det in candidates:
            # temporally-coherent sampling: the hit decision for an
            # object re-randomises every few frames, not every frame —
            # real detectors find the same object in consecutive frames,
            # which is exactly what Algorithm 1's history exploits.
            okey = hash((round(float(det.box[2]), 6),
                         round(float(det.box[3]), 6), det.category))
            rng = np.random.default_rng(
                (self.seed * 7_368_787 + okey) % (2 ** 31)
                + variant.index * 97 + (self.frame // 4) * 31)
            # effective-resolution model: the object's share of THE
            # IMAGE IT IS ANALYSED IN decides its gav size level
            level = sroi_mod.size_level_in(det, ref_sr, acc_mod.SMALL_NOA,
                                           acc_mod.MEDIUM_NOA)
            acc = float(variant.gav[level * n_cat + det.category % n_cat])
            if region is not None:
                # geometric penalties of analysing a PI (paper Fig. 1):
                # (a) objects cut by the PI border are detected poorly —
                #     CubeMap's fixed 90-degree grid splits constantly,
                #     SRoIs are centred on objects by construction;
                # (b) gnomonic stretch away from the tangent point
                #     degrades off-axis objects (1 at centre, ~cos^2 d).
                if not _fully_enclosed(det, region):
                    acc *= 0.3
                d = _angular_distance(det, region)
                acc *= max(math.cos(min(d, math.pi / 2)), 0.15) ** 2
            if rng.uniform() < acc:
                jitter = (1.0 - acc) * 0.1
                box = det.box.copy()
                box[0] += rng.normal(0, jitter * box[2])
                box[1] += rng.normal(0, jitter * box[3])
                box[2] *= float(np.exp(rng.normal(0, jitter)))
                box[3] *= float(np.exp(rng.normal(0, jitter)))
                out.append(sroi_mod.Detection(
                    box=box, category=det.category,
                    score=float(np.clip(acc + rng.normal(0, 0.05), 0.05, 1.0))))
        if fp_rng.uniform() < self.fp_rate and candidates:
            ref = candidates[0]
            out.append(sroi_mod.Detection(
                box=ref.box * np.array([1.0, 1.0, 0.7, 0.7]),
                category=int(fp_rng.integers(0, n_cat)), score=0.3))
        return out

    def infer_sroi(self, frame_img, region: sroi_mod.SRoI,
                   variant: acc_mod.ModelProfile):
        del frame_img
        gt = self.video.visible_objects(self.frame)
        cands = [d for d in gt if _in_sroi(d, region)]
        tag = hash((round(region.center[0], 3), round(region.center[1], 3))) % 9973
        return self._detect(cands, variant, tag,
                            ref_sr=sroi_mod.region_solid_angle(*region.fov),
                            region=region)

    def infer_srois_batched(self, items, variant: acc_mod.ModelProfile):
        """Batched entry point of the variant-queue machinery.

        ``items`` is a list of ``(frame_img, region)`` pairs.  The
        oracle samples from per-stream ground truth, so the "batch" is
        semantic — results are bit-identical to per-request
        :meth:`infer_sroi` calls, which is exactly what the
        batched-vs-inline equivalence tests pin.
        """
        return [self.infer_sroi(frame_img, region, variant)
                for frame_img, region in items]

    def infer_erp(self, frame_img, variant: acc_mod.ModelProfile):
        """Full-ERP inference: distortion + downsampling degrade small
        objects — modelled as a size-level demotion of the gav."""
        del frame_img
        gt = self.video.visible_objects(self.frame)
        demoted = dataclasses.replace(
            variant, gav=np.concatenate([
                variant.gav[:len(variant.gav) // 3] * 0.3,   # small: mostly lost
                variant.gav[len(variant.gav) // 3: 2 * len(variant.gav) // 3] * 0.6,
                variant.gav[2 * len(variant.gav) // 3:] * 0.9,
            ]))
        return self._detect(gt, demoted, region_tag=0, ref_sr=4 * math.pi)


class JaxDetectorBackend:
    """Real path: Pallas gnomonic projection + JAX detector inference.

    Exposes BOTH execution paths of the serving loop:

      * :meth:`infer_sroi` — the per-request path (one eager forward
        per PI), used by standalone loops and as the batching baseline;
      * :meth:`infer_srois_batched` — the pod path: the tick's crops
        for one variant are stacked, zero-padded up to a batch-size
        bucket (``repro.serving.batching.ShapeBuckets``) and pushed
        through ONE jitted ``apply`` + masked ``decode``.  The jit
        cache is keyed by (variant, padded batch), so a serving
        lifetime compiles at most ``len(buckets) * n_variants``
        distinct programs no matter how stream counts fluctuate
        (``trace_count`` counts actual retraces for the regression
        tests).
    """

    def __init__(self, variants_cfg, params_per_variant, conf: float = 0.25,
                 use_kernel: bool = True, max_det: int = 16, buckets=None,
                 fused: bool = True, crop_cache_size: int = 256):
        from repro.serving.batching import ShapeBuckets

        self.cfgs = list(variants_cfg)
        self.params = list(params_per_variant)
        self.conf = conf
        self.use_kernel = use_kernel
        self.max_det = max_det
        self.buckets = buckets or ShapeBuckets(
            resolutions=tuple(sorted({c.input_size for c in self.cfgs})))
        self._jit_cache: dict = {}
        self.trace_count = 0  # incremented at trace time only
        # fused tick: batched gnomonic projection (one dispatch per
        # chunk instead of one `_project` per crop) + a cross-tick crop
        # cache keyed on pitch-quantised region geometry.  `fused=False`
        # restores the staged per-crop path (the bench baseline).
        self.fused = fused
        self.crop_cache_size = crop_cache_size if fused else 0
        self._crop_cache: dict = {}  # key -> (guard, pi, ct, cp, fx, fy)
        self.crop_cache_hits = 0
        self.crop_cache_misses = 0

    def _project(self, frame_img, region: sroi_mod.SRoI, size: int):
        """SRoI -> (size, size, 3) PI; shared by both execution paths
        so batched and per-request crops are identical."""
        import jax.numpy as jnp

        if self.use_kernel:
            from repro.kernels.gnomonic import ops as gno_ops

            return gno_ops.project_sroi_kernel(
                jnp.asarray(frame_img), region.center[0], region.center[1],
                region.fov, (size, size))
        from repro.core.projection import project_sroi

        return project_sroi(jnp.asarray(frame_img),
                            jnp.asarray(region.center[0]),
                            jnp.asarray(region.center[1]),
                            region.fov, (size, size))

    def _row_to_dets(self, boxes, scores, classes,
                     region: sroi_mod.SRoI, size: int, geom=None):
        """Back-project one row of decoded PI boxes to SphBB detections.

        ONE vectorised ``pi_box_to_sphbb`` dispatch over the row's live
        detections (``pi_box_to_sphbb`` broadcasts over leading axes;
        bit-identical to the per-detection loop it replaced, pinned by
        ``tests/test_fused_tick.py``).  ``geom`` overrides the
        back-projection geometry — a cache hit reuses the PI projected
        at the anchor region, so its boxes must lift through the anchor
        geometry, not the (sub-pixel-drifted) query region's.
        """
        import jax.numpy as jnp

        boxes = np.asarray(boxes)
        scores = np.asarray(scores)
        classes = np.asarray(classes)
        live = np.flatnonzero(scores > 0)
        if live.size == 0:
            return []
        ct, cp, fov = (geom if geom is not None
                       else (region.center[0], region.center[1], region.fov))
        sphbbs = np.asarray(pi_box_to_sphbb(
            jnp.asarray(boxes[live]), jnp.asarray(ct), jnp.asarray(cp),
            fov, (size, size)))
        return [sroi_mod.Detection(box=sphbbs[i], category=int(classes[r]),
                                   score=float(scores[r]))
                for i, r in enumerate(live)]

    def infer_sroi(self, frame_img, region: sroi_mod.SRoI,
                   variant: acc_mod.ModelProfile):
        from repro.models import detector as det_mod

        idx = variant.index - 1
        cfg = self.cfgs[idx]
        size = cfg.input_size
        pi = self._project(frame_img, region, size)
        outs = det_mod.apply(self.params[idx], pi[None], cfg)
        boxes, scores, classes = det_mod.decode(outs, cfg, self.conf,
                                                max_det=self.max_det)
        return self._row_to_dets(boxes[0], scores[0], classes[0], region, size)

    def _batched_fn(self, idx: int, b_pad: int, group=None):
        """The jitted (apply + masked decode) program for one
        (variant, padded-batch) shape bucket — ``shard_map``-sharded
        over ``group``'s ``data`` mesh axis when a multi-device replica
        group is given (the multi-device serving path)."""
        import jax

        key = (idx, b_pad) if group is None or group.n_devices == 1 else (
            idx, b_pad, tuple(getattr(d, "id", d) for d in group.devices))
        fn = self._jit_cache.get(key)
        if fn is None:
            from repro.models import detector as det_mod

            cfg = self.cfgs[idx]

            def forward(params, imgs, valid):
                outs = det_mod.apply(params, imgs, cfg)
                return det_mod.decode(outs, cfg, self.conf,
                                      max_det=self.max_det, valid=valid)

            if len(key) == 3:
                from jax.sharding import PartitionSpec as P

                from repro.distributed.sharding import (
                    no_activation_constraints, shard_map)

                inner = forward

                def forward(params, imgs, valid):  # noqa: F811
                    # rows are independent, so per-device shards decode
                    # exactly like the unsharded batch; the training-
                    # oriented activation constraints are meaningless
                    # inside the manual (per-device) region.
                    with no_activation_constraints():
                        return shard_map(
                            inner, mesh=group.mesh,
                            in_specs=(P(), P("data"), P("data")),
                            out_specs=(P("data"), P("data"), P("data")),
                            check_vma=False)(params, imgs, valid)

            def traced(params, imgs, valid):
                self.trace_count += 1  # runs at trace time only
                return forward(params, imgs, valid)

            fn = self._jit_cache[key] = jax.jit(traced)
        return fn

    # ---- cross-tick crop cache -------------------------------------
    #
    # Static scenes re-project near-identical SRoIs tick after tick.
    # A crop is reusable when (a) the source frame is the same array
    # (identity + a strided content guard, so id() reuse after gc can
    # never alias a different frame) and (b) the region geometry moved
    # less than the bucket's pixel pitch (fov / size): quantising
    # centre and fov at the pitch makes sub-pixel drift hash to the
    # anchor's key.  Hits return the anchor's PI *and geometry*, so
    # back-projection is bit-identical to re-serving the anchor region.

    @staticmethod
    def _frame_guard(frame_img) -> bytes:
        h, w = frame_img.shape[:2]
        sample = np.asarray(frame_img[::max(1, h // 8), ::max(1, w // 8)])
        return np.ascontiguousarray(sample).tobytes()

    @staticmethod
    def _crop_key(frame_img, region: sroi_mod.SRoI, size: int):
        fx, fy = float(region.fov[0]), float(region.fov[1])
        px, py = fx / size, fy / size  # radians per output pixel
        return (id(frame_img), frame_img.shape[:2], size,
                round(float(region.center[0]) / px),
                round(float(region.center[1]) / py),
                round(fx / px), round(fy / py))

    def _cache_put(self, key, guard, pi, region: sroi_mod.SRoI) -> None:
        if len(self._crop_cache) >= self.crop_cache_size:
            self._crop_cache.pop(next(iter(self._crop_cache)))
        self._crop_cache[key] = (
            guard, pi, float(region.center[0]), float(region.center[1]),
            (float(region.fov[0]), float(region.fov[1])))

    def _project_chunk(self, chunk, size: int):
        """Project one chunk's crops: cache lookups + ONE batched
        gnomonic dispatch for the misses (padded to a batch rung so the
        projector compiles once per (bucket, ERP shape, size)).

        Returns ``(pis, geoms)`` — the (b, S, S, 3) PI stack and the
        per-item back-projection geometry (the anchor's for hits).
        """
        import jax.numpy as jnp

        from repro.kernels.gnomonic.ops import project_srois_batched

        b = len(chunk)
        rows: list = [None] * b
        geoms: list = [None] * b
        miss: list[int] = []
        guards: dict[int, bytes] = {}  # per distinct frame per chunk
        keys: list = [None] * b
        for i, (frame_img, region) in enumerate(chunk):
            geoms[i] = (region.center[0], region.center[1],
                        (float(region.fov[0]), float(region.fov[1])))
            if not self.crop_cache_size:
                miss.append(i)
                continue
            key = keys[i] = self._crop_key(frame_img, region, size)
            ent = self._crop_cache.get(key)
            if ent is not None:
                guard = guards.get(id(frame_img))
                if guard is None:
                    guard = guards[id(frame_img)] = self._frame_guard(frame_img)
                if ent[0] == guard:
                    self.crop_cache_hits += 1
                    rows[i] = ent[1]
                    geoms[i] = (ent[2], ent[3], ent[4])
                    continue
            self.crop_cache_misses += 1
            miss.append(i)
        if miss:
            b_proj = self.buckets.pad_batch(len(miss))
            pad = [miss[-1]] * (b_proj - len(miss))
            sel = miss + pad
            fresh = project_srois_batched(
                [chunk[i][0] for i in sel],
                [chunk[i][1].center for i in sel],
                [chunk[i][1].fov for i in sel], (size, size))
            for j, i in enumerate(miss):
                rows[i] = fresh[j]
                if self.crop_cache_size:
                    guard = guards.get(id(chunk[i][0]))
                    if guard is None:
                        guard = guards[id(chunk[i][0])] = self._frame_guard(
                            chunk[i][0])
                    self._cache_put(keys[i], guard, fresh[j], chunk[i][1])
        return jnp.stack(rows), geoms

    def launch_srois_batched(self, items, variant: acc_mod.ModelProfile,
                             group=None):
        """Launch the padded batched forward(s) for a tick's
        same-variant crops WITHOUT blocking on the result.

        Returns a zero-argument resolver producing the per-item
        detection lists.  Jax dispatch is asynchronous, so a caller
        that launches every replica group's forward before resolving
        any of them overlaps the V variants' inference across their
        disjoint device groups — the multi-device tick.

        With ``fused=True`` (default) the chunk's crops project in ONE
        batched gnomonic dispatch (cache hits skip projection entirely)
        instead of one ``_project`` per crop; ``fused=False`` keeps the
        staged per-crop path as the measured baseline.
        """
        import jax.numpy as jnp

        idx = variant.index - 1
        cfg = self.cfgs[idx]
        size = self.buckets.bucket_resolution(cfg.input_size)
        launched = []  # (chunk, geoms, boxes, scores, classes)
        lo = 0
        for b in self.buckets.split(len(items)):
            chunk = items[lo:lo + b]
            lo += b
            if self.fused:
                pis, geoms = self._project_chunk(chunk, size)
            else:
                pis = jnp.stack([self._project(f, r, size)
                                 for f, r in chunk])
                geoms = [None] * b
            b_pad = self.buckets.pad_batch(b)
            if group is not None and group.n_devices > 1:
                # pad further to a group-width multiple so the batch
                # axis shards evenly over the group's `data` axis
                b_pad = group.shard_batch(b_pad)
            if b_pad > b:
                pis = jnp.concatenate(
                    [pis, jnp.zeros((b_pad - b,) + pis.shape[1:], pis.dtype)])
            valid = jnp.arange(b_pad) < b
            boxes, scores, classes = self._batched_fn(idx, b_pad, group)(
                self.params[idx], pis, valid)
            launched.append((chunk, geoms, boxes, scores, classes))

        def resolve() -> list[list]:
            out: list[list] = []
            for chunk, geoms, boxes, scores, classes in launched:
                for r, (_, region) in enumerate(chunk):
                    out.append(self._row_to_dets(
                        boxes[r], scores[r], classes[r], region, size,
                        geom=geoms[r]))
            return out

        return resolve

    def infer_srois_batched(self, items, variant: acc_mod.ModelProfile,
                            group=None):
        """ONE padded batched forward for a tick's same-variant crops.

        ``items``: list of ``(frame_img, region)``.  Crops are
        projected at the variant's (bucketed) input resolution, stacked
        into (B, S, S, 3), zero-padded up to the batch bucket and run
        through the jitted forward with a validity mask; decoded rows
        back-project to SphBBs exactly like the per-request path.
        Chunks larger than the top bucket split into bucket-sized
        dispatches.  With a multi-device ``group`` the batch axis
        shards over the group's mesh (see :meth:`launch_srois_batched`,
        the non-blocking form the pod drain uses).
        """
        return self.launch_srois_batched(items, variant, group)()

    def infer_erp(self, frame_img, variant: acc_mod.ModelProfile):
        # ERP-wide pass with the largest model on the resized frame
        import jax.numpy as jnp

        from repro.core.projection import erp_resize_coords, sample_erp_bilinear
        from repro.models import detector as det_mod

        idx = variant.index - 1
        cfg = self.cfgs[idx]
        size = cfg.input_size
        u, v = erp_resize_coords((size, size), frame_img.shape[:2])
        resized = sample_erp_bilinear(jnp.asarray(frame_img), u, v)
        outs = det_mod.apply(self.params[idx], resized[None], cfg)
        boxes, scores, classes = det_mod.decode(outs, cfg, self.conf,
                                                max_det=self.max_det)
        h, w = frame_img.shape[:2]
        dets = []
        for b, s, c in zip(np.asarray(boxes[0]), np.asarray(scores[0]),
                           np.asarray(classes[0])):
            if s <= 0:
                continue
            # rectangular BB on the ERP -> SphBB via ERP coords
            x0, y0, x1, y1 = b * np.array([w / size, h / size] * 2)
            theta = ((x0 + x1) / 2 / w - 0.5) * 2 * math.pi
            phi = (0.5 - (y0 + y1) / 2 / h) * math.pi
            dth = (x1 - x0) / w * 2 * math.pi
            dph = (y1 - y0) / h * math.pi
            dets.append(sroi_mod.Detection(
                box=np.array([theta, phi, abs(dth), abs(dph)]),
                category=int(c), score=float(s)))
        return dets

"""Host-side prefetching data pipeline.

Training input must never stall the accelerator: ``Prefetcher`` wraps
any batch generator with a bounded queue filled by a daemon thread, so
host-side generation (synthetic rendering, tokenisation, target
rasterisation) overlaps device compute.  ``detector_batches`` and
``lm_batches`` are the concrete generators used by examples/tests.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


class Prefetcher:
    def __init__(self, gen: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: BaseException | None = None

        def fill():
            try:
                for item in gen:
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.put(self._done)

        self._thread = threading.Thread(target=fill, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def lm_batches(vocab: int, batch: int, seq: int, seed: int = 0,
               n_batches: int | None = None):
    """Synthetic LM token batches (markov-ish so loss can decrease)."""
    rng = np.random.default_rng(seed)
    trans = rng.integers(0, vocab, size=(vocab,))
    i = 0
    while n_batches is None or i < n_batches:
        start = rng.integers(0, vocab, size=(batch, 1))
        toks = [start]
        for _ in range(seq):
            nxt = trans[toks[-1]]
            # noise keeps it learnable-but-not-trivial
            flip = rng.random((batch, 1)) < 0.1
            rand = rng.integers(0, vocab, size=(batch, 1))
            toks.append(np.where(flip, rand, nxt))
        seq_arr = np.concatenate(toks, axis=1)
        yield {"tokens": seq_arr[:, :-1].astype(np.int32),
               "targets": seq_arr[:, 1:].astype(np.int32)}
        i += 1


def detector_batches(video, cfg, batch: int, height: int = 128,
                     width: int = 256, seed: int = 0,
                     n_batches: int | None = None):
    """Rendered ERP crops + rasterised detection targets per scale."""
    from repro.data.synthetic import render_erp

    rng = np.random.default_rng(seed)
    size = cfg.input_size
    i = 0
    while n_batches is None or i < n_batches:
        imgs, targets = [], None
        frames = rng.integers(0, video.n_frames, size=batch)
        for f in frames:
            erp = render_erp(video, int(f), height, width)
            # random crop resized to the detector input (keeps it simple)
            y0 = rng.integers(0, max(1, height - size)) if height > size else 0
            x0 = rng.integers(0, max(1, width - size)) if width > size else 0
            crop = erp[y0:y0 + size, x0:x0 + size]
            if crop.shape[0] < size or crop.shape[1] < size:
                crop = np.pad(crop, ((0, size - crop.shape[0]),
                                     (0, size - crop.shape[1]), (0, 0)))
            imgs.append(crop)
        batch_dict = {"images": np.stack(imgs).astype(np.float32)}
        targets = rasterize_targets(cfg, batch)
        batch_dict.update(targets)
        yield batch_dict
        i += 1


def rasterize_targets(cfg, batch: int, seed: int = 1):
    """Random-but-consistent dense targets for the detector loss.

    (The smoke-training example only needs the loss to be well-formed
    and decreasing; semantically meaningful targets come from the
    oracle pipeline in the serving stack.)
    """
    rng = np.random.default_rng(seed)
    out = {}
    size = cfg.input_size
    for i, stride in enumerate(cfg.strides):
        g = size // stride
        t = np.zeros((batch, g, g, 5 + cfg.n_classes), np.float32)
        n_pos = max(1, g // 4)
        for b in range(batch):
            ys = rng.integers(0, g, n_pos)
            xs = rng.integers(0, g, n_pos)
            t[b, ys, xs, 4] = 1.0
            t[b, ys, xs, 0:2] = rng.uniform(0.2, 0.8, (n_pos, 2))
            t[b, ys, xs, 2:4] = rng.uniform(-1, 1, (n_pos, 2))
            cls = rng.integers(0, cfg.n_classes, n_pos)
            t[b, ys, xs, 5 + cls] = 1.0
        out[f"targets_{i}"] = t
    return out

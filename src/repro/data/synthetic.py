"""Synthetic 360-degree scenes with ground-truth spherical annotations.

No real 360° dataset ships in this container (DESIGN.md section 7), so
scenes are generated to match the paper's measurement findings:

  * NOA distribution: log-uniform across ~4 decades (paper Fig. 2 —
    "most objects occupy a tiny area"), with per-category scale offsets
    (Fig. 3 — "same-category sizes differ by orders of magnitude");
  * spatial bias: object centres concentrate in an equatorial band,
    the sky/ground caps are near-empty (Fig. 4 / SR-3);
  * temporal dynamics: the camera yaws (driving/walking) and objects
    drift in/out of existence, so per-region object counts vary
    substantially over time (Fig. 4).

``render_erp`` rasterises a frame into an actual ERP image (objects are
painted as textured axis-aligned spherical rectangles), which feeds the
real JAX detector path and the gnomonic-projection demos.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.sroi import Detection

TWO_PI = 2.0 * math.pi


@dataclasses.dataclass
class SceneObject:
    category: int
    theta: float  # current longitude
    phi: float  # latitude
    dtheta: float  # angular width
    dphi: float  # angular height
    drift: float  # own angular velocity (rad/frame)
    born: int  # first frame
    dies: int  # last frame
    color: np.ndarray  # (3,) render colour


@dataclasses.dataclass
class SyntheticVideo:
    name: str
    n_frames: int
    objects: list[SceneObject]
    yaw_rate: float  # camera yaw per frame (rad)
    n_categories: int

    def visible_objects(self, frame: int) -> list[Detection]:
        """Ground-truth detections for one frame (camera frame coords)."""
        out = []
        yaw = self.yaw_rate * frame
        for o in self.objects:
            if not (o.born <= frame <= o.dies):
                continue
            theta = (o.theta + o.drift * frame - yaw + math.pi) % TWO_PI - math.pi
            box = np.array([theta, o.phi, o.dtheta, o.dphi], dtype=np.float64)
            out.append(Detection(box=box, category=o.category, score=1.0))
        return out


def make_video(
    name: str = "synthetic-drive",
    n_frames: int = 120,
    n_objects: int = 60,
    n_categories: int = 80,
    yaw_rate_deg: float = 0.8,
    seed: int = 0,
    noa_decades: tuple[float, float] = (-6.0, -2.2),
    polar_fraction: float = 0.05,
) -> SyntheticVideo:
    """Generate a video whose statistics match the paper's Fig. 2-4."""
    rng = np.random.default_rng(seed)
    objects = []
    cat_pool = rng.choice(n_categories, size=max(8, n_categories // 8),
                          replace=False)
    cat_scale = {int(c): rng.uniform(0.5, 2.0) for c in cat_pool}
    for _ in range(n_objects):
        cat = int(rng.choice(cat_pool))
        # log-uniform NOA; per-category multiplicative offset
        noa = 10.0 ** rng.uniform(*noa_decades) * cat_scale[cat]
        noa = min(noa, 0.03)
        # NOA = 2 * dtheta * sin(dphi / 2) / (4 pi); pick aspect ~U(0.5, 2)
        aspect = rng.uniform(0.5, 2.0)
        # solve with dphi = aspect * dtheta (small-angle): area ~ dtheta^2 * aspect
        area = noa * 4.0 * math.pi
        dtheta = min(math.sqrt(area / aspect), math.pi)
        dphi = min(aspect * dtheta, math.pi * 0.9)
        if rng.uniform() < polar_fraction:
            phi = rng.uniform(-math.pi / 2 * 0.95, math.pi / 2 * 0.95)
        else:
            phi = rng.normal(0.0, 0.25)  # equatorial band
        phi = float(np.clip(phi, -1.3, 1.3))
        if rng.uniform() < 0.5:
            born = 0  # half the population exists from the start
        else:
            born = int(rng.integers(0, max(1, n_frames - 10)))
        objects.append(SceneObject(
            category=cat,
            theta=float(rng.uniform(-math.pi, math.pi)),
            phi=phi,
            dtheta=float(dtheta),
            dphi=float(dphi),
            drift=float(rng.normal(0, 0.002)),
            born=born,
            dies=int(min(n_frames, born + rng.integers(30, 90))),
            color=rng.uniform(0.3, 1.0, size=3).astype(np.float32),
        ))
    return SyntheticVideo(name, n_frames, objects,
                          math.radians(yaw_rate_deg), n_categories)


def render_erp(video: SyntheticVideo, frame: int,
               height: int = 256, width: int = 512) -> np.ndarray:
    """Rasterise one frame to an (H, W, 3) float32 ERP image.

    Objects paint a flat colour + checker texture inside their lat/long
    footprint (adequate for detector smoke training and projection
    demos; photo-realism is out of scope).
    """
    img = np.zeros((height, width, 3), dtype=np.float32)
    # sky/ground gradient background
    lat = (0.5 - (np.arange(height) + 0.5) / height) * math.pi
    img[..., 2] = 0.15 + 0.1 * np.sin(lat)[:, None]
    img[..., 1] = 0.12
    lon = ((np.arange(width) + 0.5) / width - 0.5) * TWO_PI

    for det in video.visible_objects(frame):
        th, ph, dth, dph = det.box
        dlon = np.abs((lon - th + math.pi) % TWO_PI - math.pi)
        in_lon = dlon <= dth / 2
        in_lat = np.abs(lat - ph) <= dph / 2
        mask = np.outer(in_lat, in_lon)
        if not mask.any():
            continue
        obj = next(o for o in video.objects
                   if o.category == det.category and abs(o.phi - ph) < 1e-9)
        ys, xs = np.nonzero(mask)
        checker = (((ys // 2) + (xs // 2)) % 2).astype(np.float32) * 0.25 + 0.75
        img[ys, xs] = obj.color[None, :] * checker[:, None]
    return img


def noa_histogram(video: SyntheticVideo, frames: range) -> np.ndarray:
    """All NOA values seen over ``frames`` (for the Fig. 2 benchmark)."""
    vals = []
    for f in frames:
        for det in video.visible_objects(f):
            vals.append(det.noa())
    return np.asarray(vals)

"""Elastic scaling, failure handling and straggler mitigation.

No real multi-host fabric exists in this container, so this module
implements the *control-plane logic* a 1000-node deployment needs and
unit-tests it at simulation level (DESIGN.md section 5):

  * ``HealthTracker`` — heartbeat bookkeeping; hosts that miss
    ``max_missed`` beats are marked failed, hosts whose step time
    exceeds ``straggler_factor`` x the fleet median are stragglers;
  * ``remesh_plan`` — given the original (pod, data, model) mesh and
    the healthy host count, choose the largest feasible mesh that (a)
    preserves the ``model`` axis (TP degree is baked into compiled
    programs and checkpoint layouts), (b) shrinks ``data``/``pod``
    (pure-DP axes shrink freely: batch re-divides, FSDP shards
    re-gather from the full-array checkpoint);
  * ``StragglerPolicy`` — skip-slowest-microbatch accounting: a
    straggler's microbatch is dropped for the step and the gradient
    rescaled, bounding step time at the p50+margin instead of the max.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class HostState:
    last_beat: float = 0.0
    missed: int = 0
    step_time: float = 0.0
    failed: bool = False


class HealthTracker:
    def __init__(self, n_hosts: int, beat_interval: float = 10.0,
                 max_missed: int = 3, straggler_factor: float = 1.5):
        self.hosts = {i: HostState() for i in range(n_hosts)}
        self.beat_interval = beat_interval
        self.max_missed = max_missed
        self.straggler_factor = straggler_factor

    def heartbeat(self, host: int, now: float, step_time: float) -> None:
        h = self.hosts[host]
        h.last_beat = now
        h.missed = 0
        h.step_time = step_time

    # -- serving hooks (repro.serving.fleet) -------------------------------
    # training meshes are fixed at launch, but the serving fleet grows
    # and retires pods mid-run, so its tracker membership is dynamic.

    def ensure_host(self, host: int, now: float = 0.0) -> HostState:
        """Register ``host`` if unseen (a pod added by the elastic
        controller mid-run); idempotent for known hosts."""
        h = self.hosts.get(host)
        if h is None:
            h = self.hosts[host] = HostState(last_beat=now)
        return h

    def remove_host(self, host: int) -> None:
        """Forget a retired pod entirely — unlike a failure, a drained
        retirement must not count against health statistics."""
        self.hosts.pop(host, None)

    def tick(self, now: float) -> None:
        for h in self.hosts.values():
            if h.failed:
                continue
            if now - h.last_beat > self.beat_interval:
                h.missed += 1
                h.last_beat = now
                if h.missed >= self.max_missed:
                    h.failed = True

    def healthy(self) -> list[int]:
        return [i for i, h in self.hosts.items() if not h.failed]

    def stragglers(self) -> list[int]:
        alive = [h.step_time for h in self.hosts.values()
                 if not h.failed and h.step_time > 0]
        if not alive:
            return []
        med = sorted(alive)[len(alive) // 2]
        return [i for i, h in self.hosts.items()
                if not h.failed and h.step_time > self.straggler_factor * med]


def remesh_plan(original_shape: tuple[int, ...],
                original_axes: tuple[str, ...],
                healthy_devices: int) -> dict:
    """Largest feasible mesh on the healthy devices.

    Keeps the ``model`` axis intact, shrinks ``data`` then ``pod`` to
    the largest power-of-two product that fits.  Returns the new shape,
    the resulting global-batch scale factor, and whether a checkpoint
    reload suffices (it always does: checkpoints store full arrays).
    """
    sizes = dict(zip(original_axes, original_shape))
    model = sizes.get("model", 1)
    if healthy_devices < model:
        raise ValueError(
            f"cannot preserve model axis {model} with only "
            f"{healthy_devices} devices — requires re-lowering at a "
            f"smaller TP degree")
    budget = healthy_devices // model
    # data x pod packed into the budget, power-of-two, data-first
    data0, pod0 = sizes.get("data", 1), sizes.get("pod", 1)
    best_data = 1 << int(math.log2(max(1, min(budget, data0))))
    rem = budget // best_data
    best_pod = 1 << int(math.log2(max(1, min(rem, pod0))))
    new_sizes = {"model": model, "data": best_data, "pod": best_pod}
    shape = tuple(new_sizes[a] for a in original_axes)
    used = model * best_data * best_pod
    return {
        "shape": shape,
        "axes": original_axes,
        "devices_used": used,
        "devices_idle": healthy_devices - used,
        "batch_scale": (best_data * best_pod) / (data0 * pod0),
        "checkpoint_compatible": True,
    }


def serving_scale_plan(total_devices: int, n_pods: int) -> dict:
    """Per-pod device split for an ``n_pods`` serving fleet over a
    fixed ``total_devices`` budget — the fleet tier's consumer of
    :func:`remesh_plan`.

    The pod count plays the ``model`` axis role: it is the dimension
    that must be PRESERVED exactly (the elastic controller chose it,
    and routing state binds streams to pod identities the way TP
    degree is baked into compiled programs), while each pod's device
    width is the free ``data`` axis that shrinks to the largest
    power of two fitting the budget.  Remainder slots idle rather
    than creating unequal pods — unequal pods would make the
    router's least-loaded signal lie.
    """
    if n_pods < 1:
        raise ValueError(f"n_pods must be >= 1, got {n_pods}")
    if total_devices <= 0:
        # virtual single-device pods (the CI regime): nothing to split
        return {"n_pods": n_pods, "per_pod_devices": 0,
                "devices_used": 0, "devices_idle": 0}
    plan = remesh_plan((1, max(1, total_devices // n_pods), n_pods),
                       ("pod", "data", "model"), total_devices)
    per_pod = plan["shape"][plan["axes"].index("data")]
    return {"n_pods": n_pods, "per_pod_devices": per_pod,
            "devices_used": plan["devices_used"],
            "devices_idle": total_devices - plan["devices_used"]}


@dataclasses.dataclass
class StragglerPolicy:
    """Skip-slowest-microbatch: drop straggler contributions, rescale."""

    margin: float = 1.25
    dropped_total: int = 0

    def step(self, microbatch_times: dict[int, float]) -> dict:
        times = sorted(microbatch_times.values())
        med = times[len(times) // 2]
        cutoff = med * self.margin
        keep = {h for h, t in microbatch_times.items() if t <= cutoff}
        drop = set(microbatch_times) - keep
        self.dropped_total += len(drop)
        return {
            "keep": sorted(keep),
            "drop": sorted(drop),
            "grad_scale": len(microbatch_times) / max(len(keep), 1),
            "step_time": cutoff if drop else times[-1],
        }

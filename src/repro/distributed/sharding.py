"""Sharding rules: path-pattern -> PartitionSpec, per model family.

Strategy (see DESIGN.md section 5):

  * mesh axes ``(data, model)`` single-pod, ``(pod, data, model)``
    multi-pod.  The ``pod`` axis is pure data parallelism: batch
    dimensions shard over ``("pod", "data")`` when present, and
    parameters/optimizer state FSDP-shard over ``data`` only (so the
    inter-pod DCN link carries gradient all-reduce, not param
    all-gathers — the standard multi-slice layout).
  * LM params: Megatron TP over ``model`` (attention heads, FFN
    columns) + FSDP over ``data`` on the other matrix axis.
  * MoE: experts sharded over ``model`` (expert parallelism), dense
    attention as above.
  * KV caches: batch over ``data``; sequence axis over ``model``
    (sequence parallelism for decode — kv=1 MQA cannot shard heads).
  * vision/diffusion/detector: DP everywhere; TP over ``model`` for
    the widest matmuls (d_ff / channel axes) where divisible.

Rules are (regex, PartitionSpec) lists matched against ``path/like/this``
param paths; the first match wins.  ``spec_tree`` builds the full
PartitionSpec pytree for any param pytree.
"""

from __future__ import annotations

import re
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Sequence[tuple[str, P]]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across the rename: newer jax exposes it as
    ``jax.shard_map(check_vma=...)``, older as
    ``jax.experimental.shard_map.shard_map(check_rep=...)``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_tree(params: Any, rules: Rules, default: P = P()) -> Any:
    """Map every leaf to the PartitionSpec of the first matching rule."""

    def pick(path, leaf):
        del leaf
        ps = _path_str(path)
        for pat, spec in rules:
            if re.search(pat, ps):
                return spec
        return default

    return jax.tree_util.tree_map_with_path(pick, params)


def _filter_axes(ax):
    """Drop mesh axes that don't exist on the active mesh."""
    if ax is None:
        return None
    if isinstance(ax, tuple):
        kept = tuple(a for a in ax if a in _MESH_SIZES)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return ax if ax in _MESH_SIZES else None


def adapt_spec(spec: P) -> P:
    """Adapt a hand-written PartitionSpec to the active mesh (drops
    unknown axis names, e.g. 'pod' on single-pod meshes)."""
    out = [_filter_axes(ax) for ax in spec]
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def adapt_tree(tree):
    return jax.tree.map(adapt_spec, tree,
                        is_leaf=lambda x: isinstance(x, P))


_MESH_SIZES: dict[str, int] = {}


def set_mesh_axis_sizes(mesh: Mesh) -> None:
    """Record axis sizes so spec_tree can check divisibility."""
    global _MESH_SIZES
    _MESH_SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))


def _axis_size(ax) -> int:
    if isinstance(ax, tuple):
        return int(np.prod([_MESH_SIZES.get(a, 1) for a in ax]))
    return _MESH_SIZES.get(ax, 1)


# --------------------------------------------------------------------------
# per-family rules
# --------------------------------------------------------------------------

# batch axes: ("pod", "data") when the pod axis exists; spec_tree's
# divisibility check silently drops "pod" on single-pod meshes because
# the axis is absent from _MESH_SIZES (size 1).
BATCH = ("pod", "data")


def lm_param_rules(fsdp: bool = True, n_experts: int = 0,
                   model_axis: int = 16) -> Rules:
    """Megatron TP + optional FSDP for the LM family.

    Layer params are stacked (L, din, dout): dim 0 = layer (never
    sharded), dim 1/2 = matrix.  TP shards the 'parallel' matrix axis
    over `model`; FSDP shards the other one over `data`.

    MoE placement is adaptive: when the expert count divides the model
    axis (qwen3: 128 % 16 == 0) experts shard over `model` (EP);
    otherwise (mixtral: 8 experts on a 16-wide axis) the expert FFN
    width shards over `model` (TP-within-expert) so the big matrices
    never replicate.
    """
    d = "data" if fsdp else None
    ep = n_experts > 0 and n_experts % model_axis == 0
    rules = [
        # attention: column-parallel qkv, row-parallel out
        (r"layers/attn/wq$|layers/attn/wk$|layers/attn/wv$", P(None, d, "model")),
        (r"layers/attn/wo$", P(None, "model", d)),
        # dense mlp: column-parallel gate/up, row-parallel down
        (r"layers/mlp/w_gate$|layers/mlp/w_up$", P(None, d, "model")),
        (r"layers/mlp/w_down$", P(None, "model", d)),
        (r"layers/moe/router$", P(None, d, None)),
    ]
    if ep:
        rules += [
            (r"layers/moe/w_gate$|layers/moe/w_up$", P(None, "model", d, None)),
            (r"layers/moe/w_down$", P(None, "model", d, None)),
        ]
    else:
        rules += [
            (r"layers/moe/w_gate$|layers/moe/w_up$", P(None, None, d, "model")),
            (r"layers/moe/w_down$", P(None, None, "model", d)),
        ]
    rules += [
        # norms replicated
        (r"ln", P()),
        # embeddings: vocab over model (keeps 152k-vocab logits sharded)
        (r"embed/emb$", P("model", d)),
        (r"unembed/w$", P(d, "model")),
    ]
    return rules


def lm_batch_specs(kind: str) -> dict[str, P]:
    if kind == "train":
        return {"tokens": P(BATCH, None), "targets": P(BATCH, None)}
    if kind == "prefill":
        return {"tokens": P(BATCH, None)}
    if kind == "decode":
        # cache (L, B, S, KVH, Dh): batch over data, HEAD DIM over model.
        # Sharding S would make the per-step dynamic-update-slice (a
        # traced position into the sharded axis) trigger involuntary
        # full rematerialisation in SPMD; Dh shards cleanly for every
        # assigned KVH (1/3/4/8) and keeps the cache 256-way split.
        return {
            "token": P(BATCH),
            "cache_k": P(None, BATCH, None, None, "model"),
            "cache_v": P(None, BATCH, None, None, "model"),
            "cache_len": P(),
        }
    raise ValueError(kind)


def vision_param_rules() -> Rules:
    return [
        # ViT stacked layer matrices: (L, din, dout) — TP on dout, FSDP din
        (r"layers/wqkv$|layers/w1$", P(None, "data", "model")),
        (r"layers/wo$|layers/w2$", P(None, "model", "data")),
        # ConvNeXt pointwise convs (stacked): (L, din, dout)
        (r"stages/\d+/pw1/w$", P(None, "data", "model")),
        (r"stages/\d+/pw2/w$", P(None, "model", "data")),
        # classifier head
        (r"head/w$", P(None, "model")),
        # conv kernels (HWIO): shard output channels over model
        (r"conv|stem|dw|proj|down|lateral", P(None, None, None, "model")),
        (r".*", P()),
    ]


def vision_batch_specs() -> dict[str, P]:
    return {"images": P(BATCH, None, None, None), "labels": P(BATCH)}


def diffusion_param_rules() -> Rules:
    return [
        # MMDiT stacked stream matrices
        (r"double/(img|txt)/wqkv$|double/(img|txt)/w1$", P(None, "data", "model")),
        (r"double/(img|txt)/wo$|double/(img|txt)/w2$", P(None, "model", "data")),
        (r"single/wqkv$|single/w1$", P(None, "data", "model")),
        (r"single/wo2$", P(None, "model", "data")),
        (r"double/(img|txt)/mod/w$|single/mod/w$", P(None, None, "model")),
        # UNet transformer blocks (stacked under blocks/)
        (r"blocks/(wq1|wkv1|wq2|wkv2|ff1)/w$", P(None, None, "model")),
        (r"blocks/(wo1|wo2|ff2)/w$", P(None, "model", None)),
        # big convs: out-channels over model
        (r"conv|skip|proj", P(None, None, None, "model")),
        (r".*", P()),
    ]


def diffusion_batch_specs(cfg) -> dict[str, P]:
    from repro.models.diffusion import MMDiTConfig

    base = {"latents": P(BATCH, None, None, None), "ctx": P(BATCH, None, None)}
    if isinstance(cfg, MMDiTConfig):
        base.update({"pooled": P(BATCH, None), "guidance": P(BATCH),
                     "t": P(BATCH), "dt": P(BATCH)})
    else:
        base.update({"add_emb": P(BATCH, None), "t": P(BATCH),
                     "t_prev": P(BATCH)})
    return base


def detector_param_rules() -> Rules:
    return [
        (r"conv/w$", P(None, None, None, "model")),
        (r".*", P()),
    ]


def detector_batch_specs() -> dict[str, P]:
    return {"images": P(BATCH, None, None, None)}


# --------------------------------------------------------------------------
# activation constraints (annotated inside model code)
# --------------------------------------------------------------------------


def current_mesh():
    """The physical mesh of the active trace context, or None."""
    try:
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if not pm.empty:
            return pm
    except Exception:  # pragma: no cover
        pass
    return None


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active trace context (1 if absent)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty and name in am.axis_names:
            return dict(zip(am.axis_names, am.axis_sizes))[name]
    except Exception:  # pragma: no cover
        pass
    try:
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if not pm.empty and name in pm.axis_names:
            return dict(zip(pm.axis_names, pm.devices.shape))[name]
    except Exception:  # pragma: no cover
        pass
    return 1


import contextlib

_CONSTRAIN_ENABLED = [True]


@contextlib.contextmanager
def no_activation_constraints():
    """Disable in-model ``constrain`` calls while tracing.

    Used by serving deployments that replicate small-model weights:
    the training-oriented channel-sharding annotations would otherwise
    force reshard collectives against the replicated layout.
    """
    _CONSTRAIN_ENABLED.append(False)
    try:
        yield
    finally:
        _CONSTRAIN_ENABLED.pop()


def constrain(x, *spec):
    """``with_sharding_constraint`` that degrades gracefully.

    Models call ``constrain(x, BATCH, None, "model")`` at layer
    boundaries; outside a mesh context (CPU smoke tests) this is a
    no-op, and axes that are absent from the active mesh or don't
    divide the dimension are dropped — the same adaptation rule the
    launcher applies to the input shardings.
    """
    if not _CONSTRAIN_ENABLED[-1]:
        return x
    mesh = None
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            mesh = am
    except Exception:  # pragma: no cover
        pass
    if mesh is None:
        try:  # `with mesh:` context (legacy thread resources)
            from jax._src.mesh import thread_resources

            pm = thread_resources.env.physical_mesh
            if not pm.empty:
                mesh = pm
        except Exception:  # pragma: no cover
            pass
    if mesh is None or not mesh.axis_names:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    axes = []
    for dim, ax in enumerate(spec):
        if ax is None:
            axes.append(None)
            continue
        names = [a for a in (ax if isinstance(ax, tuple) else (ax,))
                 if a in sizes]
        if not names:
            axes.append(None)
            continue
        size = int(np.prod([sizes[a] for a in names]))
        if dim < x.ndim and x.shape[dim] % size == 0:
            axes.append(tuple(names) if len(names) > 1 else names[0])
        else:
            axes.append(None)
    return jax.lax.with_sharding_constraint(x, P(*axes))


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, spec_pytree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_pytree,
        is_leaf=lambda x: isinstance(x, P),
    )


def opt_state_specs(param_specs) -> dict:
    """AdamW moments mirror param sharding; step is replicated."""
    return {
        "mu": param_specs,
        "nu": param_specs,
        "step": P(),
    }

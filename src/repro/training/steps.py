"""Per-family train/serve step builders.

Every step is a pure function ``(state, batch[, rng]) -> (state, metrics)``
or ``(params, inputs) -> outputs`` suitable for ``jax.jit`` +
``.lower().compile()`` on any mesh — the dry-run lowers exactly these.

State layout: ``{"params": ..., "opt": ..., "step": int32}`` (plain
dicts so sharding rules apply by path).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import detector as det_mod
from repro.models import diffusion as diff_mod
from repro.models import transformer as lm_mod
from repro.models import vision as vis_mod
from repro.training import optimizer as opt_mod


def make_state(params, optimizer: opt_mod.Optimizer):
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _finish(state, optimizer, grads, loss, extra=None):
    grads, gnorm = opt_mod.clip_by_global_norm(grads, 1.0)
    new_params, new_opt = optimizer.update(grads, state["params"], state["opt"])
    metrics = {"loss": loss, "grad_norm": gnorm, "step": state["step"] + 1}
    if extra:
        metrics.update(extra)
    return ({"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics)


# -- LM ---------------------------------------------------------------------


def lm_train_step(cfg: lm_mod.TransformerConfig,
                  optimizer: opt_mod.Optimizer) -> Callable:
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_mod.lm_loss(p, batch, cfg))(state["params"])
        return _finish(state, optimizer, grads, loss)

    return step


def lm_prefill_step(cfg: lm_mod.TransformerConfig, max_len: int) -> Callable:
    def step(params, batch):
        logits, cache = lm_mod.prefill(params, batch["tokens"], cfg, max_len)
        return {"logits": logits, "k": cache.k, "v": cache.v,
                "length": cache.length}

    return step


def lm_decode_step(cfg: lm_mod.TransformerConfig) -> Callable:
    def step(params, batch):
        cache = lm_mod.KVCache(batch["cache_k"], batch["cache_v"],
                               batch["cache_len"])
        logits, new_cache = lm_mod.decode_step(params, batch["token"], cache, cfg)
        return {"logits": logits, "k": new_cache.k, "v": new_cache.v,
                "length": new_cache.length}

    return step


# -- vision -------------------------------------------------------------------

_VIS_APPLY = {
    vis_mod.ViTConfig: vis_mod.vit_apply,
    vis_mod.ConvNeXtConfig: vis_mod.convnext_apply,
    vis_mod.ResNetConfig: vis_mod.resnet_apply,
}


def vision_apply(params, images, cfg, train=False):
    return _VIS_APPLY[type(cfg)](params, images, cfg, train)


def vision_train_step(cfg, optimizer: opt_mod.Optimizer) -> Callable:
    def step(state, batch):
        def loss_fn(p):
            logits, new_p = vision_apply(p, batch["images"], cfg, train=True)
            ll = jax.nn.log_softmax(logits, axis=-1)
            loss = -jnp.mean(
                jnp.take_along_axis(ll, batch["labels"][:, None], axis=-1))
            acc = jnp.mean(
                (jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
            return loss, (acc, new_p)

        (loss, (acc, new_p)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_state, metrics = _finish(state, optimizer, grads, loss,
                                     {"accuracy": acc})
        # carry refreshed BatchNorm running stats (ResNet); grads step wins
        # for trainables, stats only exist in BN leaves marked by key name.
        if isinstance(cfg, vis_mod.ResNetConfig):
            def merge(new_stats, trained):
                return trained  # trainables already updated; stats via map below
            del merge
            new_state["params"] = _merge_bn_stats(new_state["params"], new_p)
        return new_state, metrics

    return step


def _merge_bn_stats(trained, updated):
    """Take 'mean'/'var' leaves from ``updated``, everything else trained."""

    def walk(t, u):
        if isinstance(t, dict):
            return {k: (u[k] if k in ("mean", "var") else walk(t[k], u[k]))
                    for k in t}
        if isinstance(t, list):
            return [walk(a, b) for a, b in zip(t, u)]
        return t

    return walk(trained, updated)


def vision_serve_step(cfg) -> Callable:
    def step(params, batch):
        logits, _ = vision_apply(params, batch["images"], cfg, train=False)
        return {"logits": logits}

    return step


# -- diffusion ----------------------------------------------------------------


def diffusion_train_step(cfg, optimizer: opt_mod.Optimizer) -> Callable:
    is_flux = isinstance(cfg, diff_mod.MMDiTConfig)

    def step(state, batch):
        rng = jax.random.PRNGKey(batch["seed"])
        rng = jax.random.fold_in(rng, state["step"])
        loss_fn = (lambda p: diff_mod.flux_rf_loss(p, batch, cfg, rng)) if is_flux \
            else (lambda p: diff_mod.unet_eps_loss(p, batch, cfg, rng))
        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        return _finish(state, optimizer, grads, loss)

    return step


def diffusion_denoise_step(cfg) -> Callable:
    """One sampler step (a ``steps``-step generation calls this in a loop)."""
    is_flux = isinstance(cfg, diff_mod.MMDiTConfig)

    def step(params, batch):
        if is_flux:
            x = diff_mod.flux_euler_step(
                params, batch["latents"], batch["t"], batch["dt"],
                batch["ctx"], batch["pooled"], batch["guidance"], cfg)
        else:
            x = diff_mod.unet_ddim_step(
                params, batch["latents"], batch["t"], batch["t_prev"],
                batch["ctx"], batch["add_emb"], cfg)
        return {"latents": x}

    return step


# -- detector -----------------------------------------------------------------


def detector_train_step(cfg: det_mod.DetectorConfig,
                        optimizer: opt_mod.Optimizer) -> Callable:
    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: det_mod.detection_loss(p, batch, cfg))(state["params"])
        return _finish(state, optimizer, grads, loss)

    return step


def detector_serve_step(cfg: det_mod.DetectorConfig) -> Callable:
    def step(params, batch):
        outs = det_mod.apply(params, batch["images"], cfg)
        boxes, scores, cls = det_mod.decode(outs, cfg)
        return {"boxes": boxes, "scores": scores, "classes": cls}

    return step

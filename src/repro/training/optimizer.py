"""Optimizers on raw param pytrees (AdamW, SGD-momentum, Adafactor-lite).

No optax in this container; these are small, fully-sharded-friendly
implementations: every optimizer state leaf has the same shape as its
param leaf, so FSDP-style sharding rules apply transparently.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any
OptState = dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState], tuple[Params, OptState]]
    # update(grads, params, state) -> (new_params, new_state)


def _tree_zeros(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          warmup_steps: int = 0, state_dtype=jnp.float32) -> Optimizer:
    """AdamW with optional linear warmup; moments in f32 by default."""

    def init(params):
        return {
            "mu": _tree_zeros(params, state_dtype),
            "nu": _tree_zeros(params, state_dtype),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, params, state):
        step = state["step"] + 1
        sched = jnp.where(
            warmup_steps > 0,
            jnp.minimum(1.0, step.astype(jnp.float32) / max(warmup_steps, 1)),
            1.0)
        lr_t = lr * sched
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, p, mu, nu):
            g32 = g.astype(state_dtype)
            mu_n = b1 * mu + (1 - b1) * g32
            nu_n = b2 * nu + (1 - b2) * (g32 * g32)
            mhat = mu_n / bc1
            vhat = nu_n / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(state_dtype)
            return (p.astype(state_dtype) - lr_t * delta).astype(p.dtype), mu_n, nu_n

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        out = [upd(g, p, m, n) for g, p, m, n in zip(flat_g, flat_p, flat_mu, flat_nu)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        return new_p, {"mu": new_mu, "nu": new_nu, "step": step}

    return Optimizer(init, update)


def sgd(lr: float = 0.1, momentum: float = 0.9,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"vel": _tree_zeros(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, params, state):
        def upd(g, p, v):
            g = g + weight_decay * p if weight_decay else g
            v_n = momentum * v + g
            return (p - lr * v_n).astype(p.dtype), v_n

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_v = treedef.flatten_up_to(state["vel"])
        out = [upd(g, p, v) for g, p, v in zip(flat_g, flat_p, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_p, {"vel": new_v, "step": state["step"] + 1}

    return Optimizer(init, update)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm

"""Gradient compression for the inter-pod (DCN) hop.

On a multi-pod mesh the intra-pod gradient reduction rides the ICI
(fast); the pod axis crosses data-centre network.  Two standard tricks
are provided as composable pytree transforms:

  * ``bf16_compress / bf16_decompress`` — cast the all-reduce payload
    to bf16 (2x) and accumulate the rounding error locally (error
    feedback) so compression noise does not bias the optimiser;
  * ``topk_compress / topk_decompress`` — per-leaf magnitude top-k
    sparsification (k = ratio * size) with error feedback; the
    ``CompressionState`` carries the residual between steps.

``compressed_psum`` shows the intended wiring inside a shard_map
data-parallel step; the unit tests verify the error-feedback invariant
(sum over steps of decompressed == sum of true gradients in the limit)
and end-to-end convergence on a quadratic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    residual: Any  # pytree matching grads

    @staticmethod
    def zeros_like(grads) -> "CompressionState":
        return CompressionState(jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads))


# -- bf16 with error feedback -------------------------------------------------


def bf16_compress(grads, state: CompressionState):
    def comp(g, r):
        total = g.astype(jnp.float32) + r
        q = total.astype(jnp.bfloat16)
        return q, total - q.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    pairs = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([p[0] for p in pairs]),
            CompressionState(treedef.unflatten([p[1] for p in pairs])))


def bf16_decompress(payload):
    return jax.tree.map(lambda q: q.astype(jnp.float32), payload)


# -- top-k with error feedback ------------------------------------------------


def topk_compress(grads, state: CompressionState, ratio: float = 0.1):
    """Returns ((values, indices) pytree, new state)."""

    def comp(g, r):
        total = g.astype(jnp.float32) + r
        flat = total.reshape(-1)
        k = max(1, int(flat.size * ratio))
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        del vals
        picked = flat[idx]
        kept = jnp.zeros_like(flat).at[idx].set(picked)
        return (picked, idx), total - kept.reshape(total.shape)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    pairs = [comp(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([p[0] for p in pairs]),
            CompressionState(treedef.unflatten([p[1] for p in pairs])))


def topk_decompress(payload, like):
    """(values, indices) pytree -> dense pytree shaped like ``like``."""
    flat_p, treedef = jax.tree.flatten(
        payload, is_leaf=lambda x: isinstance(x, tuple))
    flat_l = treedef.flatten_up_to(like)
    out = []
    for (vals, idx), tpl in zip(flat_p, flat_l):
        dense = jnp.zeros(tpl.size, jnp.float32).at[idx].set(vals)
        out.append(dense.reshape(tpl.shape))
    return treedef.unflatten(out)


def compression_ratio(payload, like) -> float:
    """Wire bytes of payload / wire bytes of dense f32 grads."""
    def nbytes(x):
        return x.size * x.dtype.itemsize

    dense = sum(nbytes(l) for l in jax.tree.leaves(like))
    wire = sum(nbytes(l) for l in jax.tree.leaves(payload))
    return wire / dense


# -- shard_map wiring ---------------------------------------------------------


def compressed_psum_step(grads, state: CompressionState, axis: str,
                         mode: str = "bf16"):
    """All-reduce grads over ``axis`` with compression + error feedback.

    Call INSIDE shard_map: each rank compresses its local grads, the
    payload is psum'd (bf16) or psum-of-dense-from-topk, and the dense
    f32 mean comes back.  (top-k indices differ per rank, so the
    exchanged object is the scattered dense tensor — on real fabric
    this becomes a gather of (idx, val) pairs; the wire-cost accounting
    in benchmarks uses ``compression_ratio``.)
    """
    n = jax.lax.psum(1, axis)
    if mode == "bf16":
        payload, new_state = bf16_compress(grads, state)
        summed = jax.tree.map(
            lambda q: jax.lax.psum(q.astype(jnp.float32), axis), payload)
    else:
        payload, new_state = topk_compress(grads, state)
        dense = topk_decompress(payload, grads)
        summed = jax.tree.map(lambda d: jax.lax.psum(d, axis), dense)
    mean = jax.tree.map(lambda s: s / n, summed)
    return mean, new_state

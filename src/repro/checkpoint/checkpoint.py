"""Fault-tolerant checkpointing: atomic commit, async writes, restart.

Design (what a 1000-node deployment needs, realised single-host here):

  * **atomic commit** — leaves are written to ``step_N.tmp/``, fsynced,
    then the directory is renamed to ``step_N/`` and a ``manifest.json``
    is written LAST (rename is the commit point; a crash mid-write
    leaves only an ignorable ``.tmp``);
  * **mesh signature** — the manifest records the mesh shape/axes the
    state was sharded over; ``restore`` checks compatibility and the
    elastic re-mesh planner (``repro.distributed.elastic``) decides how
    a *smaller* healthy mesh re-consumes the same checkpoint (per-leaf
    full arrays are stored, so any mesh that fits memory can reload);
  * **async writer** — ``save_async`` snapshots to host RAM
    (``jax.device_get``) on the caller thread (cheap) and does disk IO
    on a daemon thread so the train step never blocks on the
    filesystem;
  * **retention** — ``keep_n`` newest checkpoints survive GC;
  * **restart** — ``latest_step`` + ``restore`` implement the
    crash-restart path exercised by tests and the train driver.
"""

from __future__ import annotations

import json
import os
import pathlib
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep_n: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- write path ---------------------------------------------------------

    def save(self, step: int, state, mesh_signature: dict | None = None) -> None:
        host_state = jax.device_get(state)
        self._write(step, host_state, mesh_signature or {})

    def save_async(self, step: int, state,
                   mesh_signature: dict | None = None) -> None:
        """Snapshot now, write on a background thread."""
        self.wait()  # one in-flight write at a time
        host_state = jax.device_get(state)
        self._thread = threading.Thread(
            target=self._write_guarded,
            args=(step, host_state, mesh_signature or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write_guarded(self, step, host_state, sig):
        try:
            self._write(step, host_state, sig)
        except Exception as e:  # pragma: no cover - surfaced via wait()
            self._error = e

    def _write(self, step: int, host_state, sig: dict) -> None:
        leaves, treedef = _flatten(host_state)
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            for p in tmp.iterdir():
                p.unlink()
            tmp.rmdir()
        tmp.mkdir()
        np.savez(tmp / "leaves.npz",
                 **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
        with open(tmp / "manifest.json", "w") as f:
            json.dump({
                "step": step,
                "n_leaves": len(leaves),
                "treedef": str(treedef),
                "mesh": sig,
                "time": time.time(),
                "committed": True,
            }, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            for p in final.iterdir():
                p.unlink()
            final.rmdir()
        tmp.rename(final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep_n)]:
            d = self.dir / f"step_{s}"
            for p in d.iterdir():
                p.unlink()
            d.rmdir()

    # -- read path ----------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for d in self.dir.iterdir():
            if d.is_dir() and d.name.startswith("step_") \
                    and not d.name.endswith(".tmp") \
                    and (d / "manifest.json").exists():
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def manifest(self, step: int) -> dict:
        with open(self.dir / f"step_{step}" / "manifest.json") as f:
            return json.load(f)

    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (a pytree template)."""
        data = np.load(self.dir / f"step_{step}" / "leaves.npz")
        leaves, treedef = _flatten(like)
        if len(leaves) != len(data.files):
            raise ValueError(
                f"checkpoint has {len(data.files)} leaves, template has "
                f"{len(leaves)} — incompatible model/optimizer structure")
        restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
        for tpl, arr in zip(leaves, restored):
            if tuple(tpl.shape) != tuple(arr.shape):
                raise ValueError(
                    f"leaf shape mismatch: {tpl.shape} vs {arr.shape}")
        return jax.tree.unflatten(treedef, [
            np.asarray(a, dtype=t.dtype) for a, t in zip(restored, leaves)])


def mesh_signature(mesh) -> dict:
    return {"shape": list(mesh.devices.shape), "axes": list(mesh.axis_names)}

"""Training substrate demo: train, crash, restart from checkpoint.

Trains the smollm smoke config on the synthetic token pipeline with the
prefetcher, async-checkpoints every 20 steps, simulates a crash at step
50, and restarts from the latest manifest — the loss curve continues
where it left off. (~1 minute on CPU.)

    PYTHONPATH=src python examples/train_restart.py
"""

import tempfile

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import base as cfgbase
from repro.data.pipeline import Prefetcher, lm_batches
from repro.models import transformer as lm_mod
from repro.training import optimizer as opt_mod
from repro.training import steps as steps_mod


def main():
    cfg = cfgbase.get_arch("smollm_135m").smoke
    opt = opt_mod.adamw(lr=3e-3, warmup_steps=10)
    step_fn = jax.jit(steps_mod.lm_train_step(cfg, opt))

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep_n=2)

    def data():
        return Prefetcher(lm_batches(cfg.vocab_size, batch=8, seq=32,
                                     n_batches=200), depth=2)

    # ---- phase 1: train to step 50, checkpointing every 20 ----
    params = lm_mod.init_params(jax.random.PRNGKey(0), cfg)
    state = steps_mod.make_state(params, opt)
    losses = []
    it = data()
    for i, batch in zip(range(50), it):
        state, metrics = step_fn(state, {k: jax.numpy.asarray(v)
                                         for k, v in batch.items()})
        losses.append(float(metrics["loss"]))
        if (i + 1) % 20 == 0:
            mgr.save_async(i + 1, state)
    mgr.wait()
    print(f"phase 1: step 50, loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"checkpoints at {mgr.steps()}")

    # ---- simulated crash + restart ----
    del state
    latest = mgr.latest_step()
    template = steps_mod.make_state(
        lm_mod.init_params(jax.random.PRNGKey(0), cfg), opt)
    state = jax.tree.map(jax.numpy.asarray, mgr.restore(latest, template))
    print(f"restart: restored step {latest} "
          f"(optimizer step counter = {int(state['opt']['step'])})")

    it2 = data()
    for _ in zip(range(latest), it2):
        pass  # skip consumed batches (deterministic pipeline)
    more = []
    for i, batch in zip(range(50), it2):
        state, metrics = step_fn(state, {k: jax.numpy.asarray(v)
                                         for k, v in batch.items()})
        more.append(float(metrics["loss"]))
    print(f"phase 2: step {latest} -> {latest + 50}, "
          f"loss {more[0]:.3f} -> {more[-1]:.3f}")
    assert more[-1] < losses[0], "loss should keep improving after restart"
    print("\ncheckpoint/restart training substrate OK")


if __name__ == "__main__":
    main()

"""Quickstart: OmniSense on a synthetic 360-degree stream in ~10 seconds.

Runs the full per-frame loop (SRoI prediction -> latency-constrained
model allocation -> inference -> spherical NMS) against a synthetic
scene with the calibrated oracle backend and the paper-regime network
model, then reports Sph-mAP vs the CubeMap baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import baselines, profiles
from repro.serving.evaluation import sph_map
from repro.serving.network import NetworkModel
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend


def main():
    video = make_video(n_frames=28, n_objects=50, seed=3)
    frames = range(24)
    gts = [(f, d) for f in frames for d in video.visible_objects(f)]

    variants = profiles.make_ladder()
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    backend = OracleBackend(video)
    costs = [lat._pre(v) + lat._inf(v) for v in variants]
    loop = OmniSenseLoop(variants, lat, backend, budget_s=2.0,
                         explore_costs=costs)

    preds, lats = [], []
    for f in frames:
        backend.set_frame(f)
        res = loop.process_frame(None)
        preds.extend((f, d) for d in res.detections)
        lats.append(res.planned_latency)
        marks = "".join("*" if m else "." for m in
                        (res.plan.models if res.plan else []))
        print(f"frame {f:2d}: {len(res.srois):2d} SRoIs plan=[{marks}] "
              f"{len(res.detections):2d} detections "
              f"lat={res.planned_latency:.2f}s"
              f"{'  [discovery]' if res.discovered else ''}")

    acc = sph_map(preds, gts)
    print(f"\nOmniSense: Sph-mAP={acc:.3f} @ mean {np.mean(lats):.2f}s/frame")

    lat2 = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    cm_preds, cm_t = baselines.run_cubemap_baseline(
        video, OracleBackend(video), lat2, variants[2], frames)
    print(f"CubeMap-3: Sph-mAP={sph_map(cm_preds, gts):.3f} @ {cm_t:.2f}s/frame")


if __name__ == "__main__":
    main()

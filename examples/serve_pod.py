"""End-to-end serving driver: many camera streams, batched + sharded.

The paper's kind is SERVING, so the end-to-end driver multiplexes 8
synthetic 360-degree streams through the pod scheduler: every stream
runs its own OmniSense loop, PI requests that picked the same detector
variant are batched per tick, and the variants are placed onto
per-variant REPLICA GROUPS (the deployment EXPERIMENTS.md §Perf Cell C
assumes: 16-chip replica groups per variant) so the V batched forwards
run concurrently — the tick pays the max over groups, not the sum.
Since PR 4 the per-stream knapsacks are also COUPLED: the pod-level
allocator (``repro.serving.pod_allocation``) re-prices each stream's
variant costs against the co-streams' batched demand and the replica
groups' utilisation, iterating to a fixed point each tick.  Since PR 5
the tick itself is scheduled by a pluggable drain policy on the
event-clock runtime (``repro.serving.runtime``):

    PYTHONPATH=src python examples/serve_pod.py --policy sync      # barrier
    PYTHONPATH=src python examples/serve_pod.py --policy deadline  # EDF order
    PYTHONPATH=src python examples/serve_pod.py --policy async     # carry-over

Since PR 6 the pod can also be fed OPEN-LOOP, arrival-clocked traffic
(``repro.serving.traffic``): each stream's camera ticks at its own
seeded-jittered fps, the event clock advances to each arrival instead
of a global frame barrier, a frame whose predecessor still occupies
the depth-1 camera buffer is counted missed, and every arrival passes
the policy's admission hook against the SLO envelope:

    PYTHONPATH=src python examples/serve_pod.py --open-loop \
        --fps 0.5 --jitter 0.2 --slo 2.0 --admission slo

The oracle pod prices the device-aware tick model on virtual device
slots, so this runs anywhere without touching an accelerator.  The
REAL shard_map-sharded detector path needs actual jax devices; on a
machine without accelerators, force fake host devices before jax
starts — exactly what the `multidevice` CI lane and the sharded
benchmark do:

    PYTHONPATH=src:. python benchmarks/serving_bench.py --devices 8
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q -m multidevice
"""

import argparse

import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video
from repro.serving import profiles
from repro.serving.network import NetworkModel
from repro.serving.placement import VariantPlacement
from repro.serving.runtime import make_policy
from repro.serving.scheduler import OmniSenseLatencyModel, OracleBackend
from repro.serving.server import (PodServer, format_group_report,
                                  format_open_loop_report,
                                  format_pod_allocation_report)
from repro.serving.traffic import ArrivalProcess


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--policy", choices=("sync", "deadline", "async"),
                    default="sync",
                    help="drain policy of the event-clock serving runtime")
    ap.add_argument("--open-loop", action="store_true",
                    help="feed arrival-clocked open-loop traffic instead of "
                         "the closed-loop frame barrier (per-stream fps "
                         "clocks, admission control, SLO accounting)")
    ap.add_argument("--fps", type=float, default=0.5,
                    help="per-stream arrival rate for --open-loop")
    ap.add_argument("--jitter", type=float, default=0.2,
                    help="lognormal sigma on open-loop inter-arrival times")
    ap.add_argument("--slo", type=float, default=2.0,
                    help="end-to-end SLO for open-loop goodput accounting")
    ap.add_argument("--admission", choices=("admit-all", "slo"),
                    default="admit-all",
                    help="open-loop admission policy: admit everything, or "
                         "degrade/reject when projected load exceeds the "
                         "SLO envelope")
    args = ap.parse_args()

    variants = profiles.make_ladder()
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    costs = [lat._pre(v) + lat._inf(v) for v in variants]

    loops, backends = [], []
    for s in range(args.streams):
        video = make_video(n_frames=args.frames + 8, n_objects=30 + 5 * s,
                           seed=100 + s)
        backend = OracleBackend(video)
        backends.append(backend)
        loops.append(OmniSenseLoop(variants, lat, backend, budget_s=1.8,
                                   explore_costs=costs))

    placement = VariantPlacement.virtual(variants, args.devices,
                                         cost_fn=lat._inf)
    # pod_allocate on the policy: the per-stream knapsacks are coupled
    # each tick by the fixed-point pod-level allocator (amortized
    # batched costs + per-group queue depth/utilisation), so streams
    # prefer variants whose replica groups are idle instead of
    # planning solo.  The fixed point is tick-batch-synchronous, so
    # the arrival-driven open loop runs the per-stream allocator with
    # the admission hook instead.
    policy = make_policy(args.policy, pod_allocate=not args.open_loop,
                         admission=args.admission if args.open_loop
                         else None)
    server = PodServer(loops, backends, max_batch=8, placement=placement,
                       policy=policy)
    if args.open_loop:
        horizon_s = args.frames / args.fps
        traffic = ArrivalProcess(args.streams, fps=args.fps,
                                 jitter=args.jitter, seed=0,
                                 horizon_s=horizon_s)
        stats = server.run_open_loop(traffic, slo_s=args.slo)
    else:
        stats = server.run(range(args.frames))

    print(f"streams: {args.streams}, frames/stream: {args.frames}, "
          f"policy: {stats.policy}")
    print(f"total frames served: {stats.frames}")
    print(f"total detections:    {stats.total_detections}")
    print(f"mean per-frame plan latency: {stats.mean_e2e:.2f}s "
          f"(budget 1.8s)")
    print(f"mean control-plane overhead: "
          f"{1e3 * stats.sum_overhead / stats.frames:.2f} ms/frame")
    if stats.batch_sizes:
        hist = np.bincount(stats.batch_sizes)
        print(f"variant batch sizes: mean={stats.mean_batch:.2f} "
              f"hist={dict(enumerate(hist.tolist()))}")
    print(f"batched dispatches: {stats.dispatches} "
          f"(inference {stats.sum_batched_inf_s:.1f}s batched vs "
          f"{stats.sum_per_request_inf_s:.1f}s per-request -> "
          f"{stats.batching_gain:.2f}x)")
    pct = stats.event_e2e_percentiles()
    print(f"event clock: mean tick {stats.mean_tick:.3f}s, E2E "
          f"p50/p95/p99 = {pct[50]:.2f}/{pct[95]:.2f}/{pct[99]:.2f}s, "
          f"{stats.carried_requests} carried requests "
          f"({stats.carry_tick_slots} request-ticks)")
    for line in format_group_report(stats, placement):
        print(line)
    if args.open_loop:
        for line in format_open_loop_report(stats, horizon_s):
            print(line)
    else:
        print(format_pod_allocation_report(stats))
    print("\npod serving loop OK")


if __name__ == "__main__":
    main()

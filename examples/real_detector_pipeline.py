"""The REAL inference path, end to end, on actual pixels.

Renders synthetic ERP frames, extracts SRoI perspective images with the
Pallas gnomonic kernel (interpret mode on CPU), runs the JAX CSP
detector ladder on them, back-projects detections to SphBBs and applies
spherical NMS — i.e. every data-plane stage of the paper's Fig. 5 with
no oracle anywhere. Detectors are randomly initialised (no pretrained
weights offline), so boxes are not semantically meaningful; the point
is the full pipeline executing on real tensors.

    PYTHONPATH=src python examples/real_detector_pipeline.py
"""

import dataclasses
import math
import time

import jax
import numpy as np

from repro.core.omnisense import OmniSenseLoop
from repro.data.synthetic import make_video, render_erp
from repro.models import detector as det_mod
from repro.serving import profiles
from repro.serving.network import NetworkModel
from repro.serving.scheduler import JaxDetectorBackend, OmniSenseLatencyModel


def main():
    video = make_video(n_frames=6, n_objects=20, seed=7)
    # reduced detector ladder (CPU-friendly input sizes)
    cfgs = [dataclasses.replace(c, input_size=max(64, c.input_size // 8
                                                  // 32 * 32),
                                n_classes=16)
            for c in det_mod.PAPER_LADDER[:3]]
    params = [det_mod.init_params(jax.random.PRNGKey(i), c)
              for i, c in enumerate(cfgs)]
    variants = profiles.make_ladder(n_categories=16)[:3]
    backend = JaxDetectorBackend(cfgs, params, conf=0.05, max_det=4)
    lat = OmniSenseLatencyModel(profiles.paper_profile(), NetworkModel())
    loop = OmniSenseLoop(variants, lat, backend, budget_s=2.0,
                         n_categories=16,
                         explore_costs=[0.1, 0.2, 0.3])

    for f in range(3):
        frame = render_erp(video, f, height=192, width=384)
        t0 = time.perf_counter()
        res = loop.process_frame(frame)
        wall = time.perf_counter() - t0
        print(f"frame {f}: {len(res.srois)} SRoIs -> "
              f"{len(res.detections)} SphBB detections "
              f"(host wall {wall:.2f}s, incl. jit compiles on first frames)")
        for d in res.detections[:3]:
            print(f"    cat={d.category:2d} score={d.score:.2f} "
                  f"box=({d.box[0]:+.2f},{d.box[1]:+.2f},"
                  f"{d.box[2]:.2f},{d.box[3]:.2f})")
    print("\nfull real-tensor pipeline OK "
          "(gnomonic Pallas kernel -> detector -> SphBB -> spherical NMS)")


if __name__ == "__main__":
    main()
